//! Per-worker local storage of a distributed matrix: the rows this worker
//! owns under the matrix's layout, packed densely in local-index order.

use crate::elemental::Layout;
use crate::linalg::DenseMatrix;
use crate::protocol::MatrixMeta;
use crate::{Error, Result};

/// One worker's slice of a distributed matrix.
#[derive(Debug, Clone)]
pub struct LocalPanel {
    pub meta: MatrixMeta,
    /// This worker's slot index within `meta.layout.owners`.
    pub slot: u32,
    layout: Layout,
    /// `local_count(slot) x meta.cols` row-major storage.
    local: DenseMatrix,
    /// Count of *distinct* local rows stored so far (see `filled`).
    rows_received: u64,
    /// Bitset over local row indices: which rows have been stored.
    /// Makes `set_row` idempotent in the count — a client resending an
    /// unacknowledged upload slab after a reconnect must not inflate
    /// `rows_received` (and a duplicate row must not mask a missing one
    /// in the transfer-complete check).
    filled: Vec<u64>,
}

impl LocalPanel {
    /// Allocate a zeroed panel for `slot` of the matrix described by `meta`.
    pub fn alloc(meta: MatrixMeta, slot: u32) -> Result<LocalPanel> {
        let layout = Layout::from_desc(&meta.layout, meta.rows)?;
        if slot >= layout.slots {
            return Err(Error::Shape(format!(
                "slot {slot} out of range ({} owners)",
                layout.slots
            )));
        }
        let local_rows = layout.local_count(slot) as usize;
        Ok(LocalPanel {
            slot,
            layout,
            local: DenseMatrix::zeros(local_rows, meta.cols as usize),
            rows_received: 0,
            filled: vec![0u64; local_rows.div_ceil(64)],
            meta,
        })
    }

    /// Build a panel directly from pre-packed local storage (routines
    /// producing distributed outputs use this).
    pub fn from_local(meta: MatrixMeta, slot: u32, local: DenseMatrix) -> Result<LocalPanel> {
        let layout = Layout::from_desc(&meta.layout, meta.rows)?;
        if local.shape() != (layout.local_count(slot) as usize, meta.cols as usize) {
            return Err(Error::Shape(format!(
                "panel shape {:?} != expected {}x{}",
                local.shape(),
                layout.local_count(slot),
                meta.cols
            )));
        }
        let rows_received = local.rows() as u64;
        let filled = vec![u64::MAX; local.rows().div_ceil(64)];
        Ok(LocalPanel { slot, layout, local, rows_received, filled, meta })
    }

    pub fn layout(&self) -> Layout {
        self.layout
    }

    pub fn local(&self) -> &DenseMatrix {
        &self.local
    }

    pub fn local_mut(&mut self) -> &mut DenseMatrix {
        &mut self.local
    }

    pub fn local_rows(&self) -> usize {
        self.local.rows()
    }

    pub fn rows_received(&self) -> u64 {
        self.rows_received
    }

    /// Store global row `r` (must be owned by our slot).
    pub fn set_row(&mut self, r: u64, values: &[f64]) -> Result<()> {
        if values.len() != self.meta.cols as usize {
            return Err(Error::Shape(format!(
                "row length {} != cols {}",
                values.len(),
                self.meta.cols
            )));
        }
        if !self.layout.owns(self.slot, r) {
            return Err(Error::Server(format!(
                "row {r} routed to wrong worker (slot {} owns it, we are slot {})",
                self.layout.owner_slot(r),
                self.slot
            )));
        }
        let li = self.layout.local_index(r) as usize;
        self.local.row_mut(li).copy_from_slice(values);
        let (word, bit) = (li / 64, 1u64 << (li % 64));
        if self.filled[word] & bit == 0 {
            self.filled[word] |= bit;
            self.rows_received += 1;
        }
        Ok(())
    }

    /// Read global row `r` (must be locally stored).
    pub fn get_row(&self, r: u64) -> Result<&[f64]> {
        if !self.layout.owns(self.slot, r) {
            return Err(Error::Server(format!("row {r} not owned by slot {}", self.slot)));
        }
        Ok(self.local.row(self.layout.local_index(r) as usize))
    }

    /// Iterate (global_row, values) in local order.
    pub fn iter_rows(&self) -> impl Iterator<Item = (u64, &[f64])> + '_ {
        (0..self.local.rows()).map(move |li| {
            (self.layout.global_index(self.slot, li as u64), self.local.row(li))
        })
    }
}

/// Test helper: split a full matrix into per-slot panels.
pub fn scatter_matrix(meta: &MatrixMeta, full: &DenseMatrix) -> Result<Vec<LocalPanel>> {
    let layout = Layout::from_desc(&meta.layout, meta.rows)?;
    if full.shape() != (meta.rows as usize, meta.cols as usize) {
        return Err(Error::Shape("scatter: full matrix shape mismatch".into()));
    }
    let mut panels = Vec::new();
    for slot in 0..layout.slots {
        let mut p = LocalPanel::alloc(meta.clone(), slot)?;
        for r in layout.rows_of_slot(slot) {
            p.set_row(r, full.row(r as usize))?;
        }
        panels.push(p);
    }
    Ok(panels)
}

/// Test helper: reassemble a full matrix from all panels. Replicated
/// matrices are read from the first panel alone (every panel holds the
/// full matrix).
pub fn gather_matrix(panels: &[LocalPanel]) -> Result<DenseMatrix> {
    let meta = &panels[0].meta;
    let mut full = DenseMatrix::zeros(meta.rows as usize, meta.cols as usize);
    let mut seen = 0u64;
    let read_from: &[LocalPanel] = if meta.layout.kind == crate::protocol::LayoutKind::Replicated
    {
        &panels[..1]
    } else {
        panels
    };
    for p in read_from {
        for (r, row) in p.iter_rows() {
            full.row_mut(r as usize).copy_from_slice(row);
            seen += 1;
        }
    }
    if seen != meta.rows {
        return Err(Error::Shape(format!("gathered {seen} rows, expected {}", meta.rows)));
    }
    Ok(full)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{LayoutDesc, LayoutKind};
    use crate::workload::random_matrix;

    fn meta(rows: u64, cols: u64, kind: LayoutKind, p: u32) -> MatrixMeta {
        MatrixMeta {
            handle: 1,
            rows,
            cols,
            layout: LayoutDesc { kind, owners: (0..p).collect() },
        }
    }

    #[test]
    fn scatter_gather_roundtrip_both_layouts() {
        for kind in [LayoutKind::RowBlock, LayoutKind::RowCyclic] {
            for p in [1, 2, 3, 5] {
                let m = meta(17, 4, kind, p);
                let full =
                    DenseMatrix::from_vec(17, 4, random_matrix(9, 17, 4)).unwrap();
                let panels = scatter_matrix(&m, &full).unwrap();
                assert_eq!(panels.len(), p as usize);
                let back = gather_matrix(&panels).unwrap();
                assert_eq!(back, full, "{kind:?} p={p}");
            }
        }
    }

    #[test]
    fn misrouted_row_rejected() {
        let m = meta(10, 2, LayoutKind::RowBlock, 2);
        let mut p0 = LocalPanel::alloc(m, 0).unwrap();
        // rows 0..5 belong to slot 0; row 7 belongs to slot 1
        assert!(p0.set_row(7, &[1.0, 2.0]).is_err());
        assert!(p0.set_row(2, &[1.0, 2.0]).is_ok());
        assert_eq!(p0.rows_received(), 1);
    }

    #[test]
    fn duplicate_set_row_does_not_inflate_rows_received() {
        // A resumed upload replays unacknowledged slabs; the count must
        // track distinct rows, or a replay would satisfy the
        // transfer-complete check with rows still missing.
        let m = meta(4, 2, LayoutKind::RowBlock, 1);
        let mut p = LocalPanel::alloc(m, 0).unwrap();
        p.set_row(1, &[1.0, 2.0]).unwrap();
        p.set_row(1, &[3.0, 4.0]).unwrap();
        assert_eq!(p.rows_received(), 1);
        assert_eq!(p.get_row(1).unwrap(), &[3.0, 4.0]);
        for r in [0u64, 2, 3] {
            p.set_row(r, &[0.5, 0.5]).unwrap();
        }
        assert_eq!(p.rows_received(), 4);
    }

    #[test]
    fn wrong_row_length_rejected() {
        let m = meta(4, 3, LayoutKind::RowBlock, 1);
        let mut p = LocalPanel::alloc(m, 0).unwrap();
        assert!(p.set_row(0, &[1.0]).is_err());
    }

    #[test]
    fn get_row_reads_back() {
        let m = meta(6, 2, LayoutKind::RowCyclic, 2);
        let mut p1 = LocalPanel::alloc(m, 1).unwrap();
        p1.set_row(3, &[9.0, 8.0]).unwrap();
        assert_eq!(p1.get_row(3).unwrap(), &[9.0, 8.0]);
        assert!(p1.get_row(2).is_err());
    }

    #[test]
    fn from_local_validates_shape() {
        let m = meta(10, 2, LayoutKind::RowBlock, 2);
        let ok = DenseMatrix::zeros(5, 2);
        assert!(LocalPanel::from_local(m.clone(), 0, ok).is_ok());
        let bad = DenseMatrix::zeros(4, 2);
        assert!(LocalPanel::from_local(m, 0, bad).is_err());
    }

    #[test]
    fn out_of_range_slot_rejected() {
        let m = meta(10, 2, LayoutKind::RowBlock, 2);
        assert!(LocalPanel::alloc(m, 5).is_err());
    }

    #[test]
    fn replicated_panels_hold_full_copies() {
        let m = meta(5, 2, LayoutKind::Replicated, 3);
        let full = DenseMatrix::from_vec(5, 2, random_matrix(11, 5, 2)).unwrap();
        let panels = scatter_matrix(&m, &full).unwrap();
        assert_eq!(panels.len(), 3);
        for p in &panels {
            assert_eq!(p.local_rows(), 5, "every slot stores every row");
            for (r, row) in p.iter_rows() {
                assert_eq!(row, full.row(r as usize));
            }
            // any slot serves any row
            assert_eq!(p.get_row(4).unwrap(), full.row(4));
        }
        assert_eq!(gather_matrix(&panels).unwrap(), full);
    }
}
