//! Worker-local registry of distributed-matrix panels, keyed by handle.
//! This is the storage behind the paper's `AlMatrix` handles: matrices
//! live here, on the Alchemist side, across library calls; data only moves
//! when the client explicitly sends or fetches it.

use std::collections::HashMap;

use crate::elemental::LocalPanel;
use crate::{Error, Result};

/// One worker's panel store.
#[derive(Debug, Default)]
pub struct MatrixStore {
    panels: HashMap<u64, LocalPanel>,
}

impl MatrixStore {
    pub fn new() -> MatrixStore {
        MatrixStore::default()
    }

    pub fn insert(&mut self, panel: LocalPanel) -> Result<()> {
        let h = panel.meta.handle;
        if self.panels.contains_key(&h) {
            return Err(Error::Server(format!("handle {h} already exists")));
        }
        self.panels.insert(h, panel);
        Ok(())
    }

    pub fn get(&self, handle: u64) -> Result<&LocalPanel> {
        self.panels
            .get(&handle)
            .ok_or_else(|| Error::Server(format!("unknown matrix handle {handle}")))
    }

    pub fn get_mut(&mut self, handle: u64) -> Result<&mut LocalPanel> {
        self.panels
            .get_mut(&handle)
            .ok_or_else(|| Error::Server(format!("unknown matrix handle {handle}")))
    }

    pub fn remove(&mut self, handle: u64) -> Result<LocalPanel> {
        self.panels
            .remove(&handle)
            .ok_or_else(|| Error::Server(format!("unknown matrix handle {handle}")))
    }

    pub fn contains(&self, handle: u64) -> bool {
        self.panels.contains_key(&handle)
    }

    /// All stored handles (worker reset sweeps these through the runtime
    /// cache before dropping the panels).
    pub fn handles(&self) -> Vec<u64> {
        self.panels.keys().copied().collect()
    }

    /// Drop every panel — worker-wide reset on re-registration or a
    /// driver `WorkerCtl::Reset` (session-scoped cleanup uses `remove`).
    pub fn clear(&mut self) {
        self.panels.clear();
    }

    pub fn len(&self) -> usize {
        self.panels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.panels.is_empty()
    }

    /// Total locally-stored bytes (memory accounting / metrics).
    pub fn local_bytes(&self) -> u64 {
        self.panels
            .values()
            .map(|p| (p.local().rows() * p.local().cols() * 8) as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{LayoutDesc, LayoutKind, MatrixMeta};

    fn panel(handle: u64, rows: u64) -> LocalPanel {
        let meta = MatrixMeta {
            handle,
            rows,
            cols: 2,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: vec![0] },
        };
        LocalPanel::alloc(meta, 0).unwrap()
    }

    #[test]
    fn insert_get_remove_lifecycle() {
        let mut s = MatrixStore::new();
        assert!(s.is_empty());
        s.insert(panel(1, 4)).unwrap();
        s.insert(panel(2, 8)).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(1).unwrap().meta.rows, 4);
        assert!(s.get(3).is_err());
        assert_eq!(s.local_bytes(), (4 + 8) * 2 * 8);
        s.remove(1).unwrap();
        assert!(s.get(1).is_err());
        assert!(s.remove(1).is_err());
    }

    #[test]
    fn clear_drops_everything() {
        let mut s = MatrixStore::new();
        s.insert(panel(1, 4)).unwrap();
        s.insert(panel(2, 8)).unwrap();
        assert_eq!(s.handles().len(), 2);
        s.clear();
        assert!(s.is_empty());
        assert!(s.handles().is_empty());
        // a cleared store accepts previously used handles again
        s.insert(panel(1, 4)).unwrap();
    }

    #[test]
    fn duplicate_handle_rejected() {
        let mut s = MatrixStore::new();
        s.insert(panel(1, 4)).unwrap();
        assert!(s.insert(panel(1, 6)).is_err());
    }
}
