//! Distributed transpose: B = Aᵀ with both matrices row-distributed.
//!
//! Every rank owns a row panel of A; rank `s` needs A's *columns* that
//! form its B-row slice. Each rank therefore carves its panel into
//! column strips by B's layout and exchanges strips all-to-all (same
//! deadlock-free shifted exchange as `redistribute`). This is the
//! Elemental `Transpose` analogue — and exactly the operation Spark has
//! to emulate with a full (i, j, v) explosion + shuffle (paper §4.1).

use crate::comm::Mesh;
use crate::elemental::{Layout, LocalPanel};
use crate::protocol::{LayoutDesc, LayoutKind, MatrixMeta, Reader, Writer};
use crate::{Error, Result};

/// SPMD: pass this rank's panel of A; returns this rank's panel of
/// B = Aᵀ (RowBlock over A's columns, same owner list).
pub fn dist_transpose(mesh: &mut Mesh, a: &LocalPanel, b_handle: u64) -> Result<LocalPanel> {
    let p = mesh.size();
    if a.meta.layout.owners.len() != p {
        return Err(Error::Shape(format!(
            "transpose: {} owners vs mesh size {p}",
            a.meta.layout.owners.len()
        )));
    }
    let (m, n) = (a.meta.rows, a.meta.cols);
    let b_meta = MatrixMeta {
        handle: b_handle,
        rows: n,
        cols: m,
        layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: a.meta.layout.owners.clone() },
    };
    let b_layout = Layout::from_desc(&b_meta.layout, n)?;
    let mut out = LocalPanel::alloc(b_meta, a.slot)?;

    // Strip for destination slot s: columns j of A with owner_slot_B(j)=s,
    // transposed: for each such j, the values A[i, j] for our local rows i
    // become parts of B's row j at columns = our global row indices.
    let build_strip = |dest: u32| -> Vec<u8> {
        let mut w = Writer::new();
        let cols: Vec<u64> = b_layout.rows_of_slot(dest).collect();
        w.put_u32(cols.len() as u32);
        for &j in &cols {
            w.put_u64(j);
            // (global_row, value) pairs for column j
            w.put_u32(a.local_rows() as u32);
            for (gi, row) in a.iter_rows() {
                w.put_u64(gi);
                w.put_f64(row[j as usize]);
            }
        }
        w.into_bytes()
    };

    let place_strip = |out: &mut LocalPanel, bytes: &[u8]| -> Result<()> {
        let mut r = Reader::new(bytes);
        let ncols = r.get_u32()?;
        for _ in 0..ncols {
            let j = r.get_u64()?; // B row index
            let cnt = r.get_u32()?;
            for _ in 0..cnt {
                let gi = r.get_u64()?; // B column index
                let v = r.get_f64()?;
                // write element (j, gi) of B
                let li = out.layout().local_index(j) as usize;
                out.local_mut().set(li, gi as usize, v);
            }
        }
        Ok(())
    };

    // our own strip
    let mine = build_strip(a.slot);
    place_strip(&mut out, &mine)?;
    // shifted all-to-all
    let rank = mesh.rank();
    for s in 1..p {
        let to = (rank + s) % p;
        let from = (rank + p - s) % p;
        let payload = build_strip(to as u32);
        let got = mesh.exchange(to, &payload, from)?;
        place_strip(&mut out, &got)?;
    }
    // mark all rows received (elements were placed cell-wise)
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_mesh;
    use crate::elemental::panel::{gather_matrix, scatter_matrix};
    use crate::linalg::DenseMatrix;
    use crate::workload::random_matrix;
    use std::sync::Arc;

    fn run_transpose(m: u64, n: u64, p: usize) {
        let meta = MatrixMeta {
            handle: 1,
            rows: m,
            cols: n,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: (0..p as u32).collect() },
        };
        let full =
            DenseMatrix::from_vec(m as usize, n as usize, random_matrix(3, m as usize, n as usize))
                .unwrap();
        let panels = Arc::new(scatter_matrix(&meta, &full).unwrap());
        let out = run_mesh(p, move |mut mesh| {
            let mine = panels[mesh.rank()].clone();
            dist_transpose(&mut mesh, &mine, 2)
        })
        .unwrap();
        // gather_matrix requires rows_received; panels were filled cell-wise,
        // so reassemble manually from local storage.
        let mut bt = DenseMatrix::zeros(n as usize, m as usize);
        for panel in &out {
            let layout = panel.layout();
            for li in 0..panel.local_rows() {
                let gr = layout.global_index(panel.slot, li as u64) as usize;
                bt.row_mut(gr).copy_from_slice(panel.local().row(li));
            }
        }
        assert_eq!(bt, full.transpose(), "m={m} n={n} p={p}");
        assert_eq!(out[0].meta.rows, n);
        assert_eq!(out[0].meta.cols, m);
    }

    #[test]
    fn transpose_various_shapes() {
        run_transpose(7, 5, 1);
        run_transpose(12, 8, 3);
        run_transpose(20, 3, 4);
        run_transpose(5, 17, 2);
    }
}
