//! Row-distribution math shared by the client (routing rows to workers on
//! send), the workers (local storage addressing) and the redistribution
//! kernels. Pure functions of (`LayoutKind`, total rows, #owners) — the
//! proptest suite checks the partition-function invariants (every row has
//! exactly one owner slot; local/global maps are inverse bijections).

use crate::protocol::{LayoutDesc, LayoutKind};
use crate::{Error, Result};

/// Concrete layout of `rows` matrix rows over `slots` owner slots.
/// A *slot* is an index into `LayoutDesc::owners`; the worker id living in
/// that slot is a server-side concern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Layout {
    pub kind: LayoutKind,
    pub rows: u64,
    pub slots: u32,
}

impl Layout {
    pub fn new(kind: LayoutKind, rows: u64, slots: u32) -> Result<Layout> {
        if slots == 0 {
            return Err(Error::Shape("layout needs >= 1 slot".into()));
        }
        Ok(Layout { kind, rows, slots })
    }

    pub fn from_desc(desc: &LayoutDesc, rows: u64) -> Result<Layout> {
        Layout::new(desc.kind, rows, desc.owners.len() as u32)
    }

    /// Rows per block in the RowBlock layout.
    fn block(&self) -> u64 {
        let p = self.slots as u64;
        (self.rows + p - 1) / p
    }

    /// Which slot owns global row `r`. For `Replicated` layouts every
    /// slot stores every row; this returns the *canonical* owner (slot 0)
    /// — the one fetches should read from. Use [`Layout::owns`] for
    /// storage-membership checks.
    pub fn owner_slot(&self, r: u64) -> u32 {
        debug_assert!(r < self.rows);
        match self.kind {
            LayoutKind::RowBlock => {
                let b = self.block().max(1);
                ((r / b).min(self.slots as u64 - 1)) as u32
            }
            LayoutKind::RowCyclic => (r % self.slots as u64) as u32,
            LayoutKind::Replicated => 0,
        }
    }

    /// True when `slot` stores global row `r` (every slot, for
    /// `Replicated`; exactly the owner slot otherwise).
    pub fn owns(&self, slot: u32, r: u64) -> bool {
        match self.kind {
            LayoutKind::Replicated => slot < self.slots,
            _ => self.owner_slot(r) == slot,
        }
    }

    /// Local row index of global row `r` within its owner's panel.
    pub fn local_index(&self, r: u64) -> u64 {
        match self.kind {
            LayoutKind::RowBlock => r - self.owner_slot(r) as u64 * self.block().max(1),
            LayoutKind::RowCyclic => r / self.slots as u64,
            LayoutKind::Replicated => r,
        }
    }

    /// Number of rows stored by `slot`.
    pub fn local_count(&self, slot: u32) -> u64 {
        debug_assert!(slot < self.slots);
        match self.kind {
            LayoutKind::RowBlock => {
                let b = self.block();
                let start = (slot as u64 * b).min(self.rows);
                let end = ((slot as u64 + 1) * b).min(self.rows);
                end - start
            }
            LayoutKind::RowCyclic => {
                let p = self.slots as u64;
                let s = slot as u64;
                if s < self.rows % p {
                    self.rows / p + 1
                } else {
                    self.rows / p
                }
            }
            LayoutKind::Replicated => self.rows,
        }
    }

    /// Global row index of local row `li` on `slot` (inverse of
    /// `local_index` restricted to the slot).
    pub fn global_index(&self, slot: u32, li: u64) -> u64 {
        match self.kind {
            LayoutKind::RowBlock => slot as u64 * self.block() + li,
            LayoutKind::RowCyclic => li * self.slots as u64 + slot as u64,
            LayoutKind::Replicated => li,
        }
    }

    /// Iterator over the global rows owned by `slot`, in local order.
    pub fn rows_of_slot(&self, slot: u32) -> impl Iterator<Item = u64> + '_ {
        let count = self.local_count(slot);
        (0..count).map(move |li| self.global_index(slot, li))
    }
}

/// A p_r × p_c process grid over the ranks of a session mesh, row-major:
/// rank r sits at grid position (r / p_c, r % p_c). The 1D layouts are the
/// degenerate cases — p×1 is RowBlock's view of the world, 1×p is its
/// transpose.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Grid {
    pub p_r: u32,
    pub p_c: u32,
}

impl Grid {
    pub fn new(p_r: u32, p_c: u32) -> Result<Grid> {
        if p_r == 0 || p_c == 0 {
            return Err(Error::Shape("process grid needs >= 1 rank per dimension".into()));
        }
        Ok(Grid { p_r, p_c })
    }

    /// The most-square factorization of `p`: p_r·p_c == p with p_r ≥ p_c
    /// and p_c the largest divisor of p at most √p. Perfect squares give
    /// √p × √p; primes degenerate to p×1 (the 1D ring shape).
    pub fn auto(p: u32) -> Grid {
        assert!(p > 0, "grid over an empty mesh");
        let mut d = 1u32;
        let mut c = 1u32;
        while d * d <= p {
            if p % d == 0 {
                c = d;
            }
            d += 1;
        }
        Grid { p_r: p / c, p_c: c }
    }

    pub fn size(&self) -> u32 {
        self.p_r * self.p_c
    }

    /// Grid row of mesh rank `rank`.
    pub fn row_of(&self, rank: u32) -> u32 {
        debug_assert!(rank < self.size());
        rank / self.p_c
    }

    /// Grid column of mesh rank `rank`.
    pub fn col_of(&self, rank: u32) -> u32 {
        debug_assert!(rank < self.size());
        rank % self.p_c
    }

    /// Mesh rank at grid position (r, c).
    pub fn rank_of(&self, r: u32, c: u32) -> u32 {
        debug_assert!(r < self.p_r && c < self.p_c);
        r * self.p_c + c
    }
}

/// Config / routine-param spelling of a process grid: `"auto"` (resolve to
/// the most-square factorization of the grant size) or an explicit
/// `"RxC"`. Divisibility against the actual rank count is checked at
/// [`GridSpec::resolve`] time — parsing only validates the spelling, so
/// the driver can pre-admit requests before the grant size is known.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GridSpec {
    #[default]
    Auto,
    Fixed(u32, u32),
}

impl GridSpec {
    pub fn parse(s: &str) -> Result<GridSpec> {
        if s == "auto" {
            return Ok(GridSpec::Auto);
        }
        let bad = || Error::Config(format!("grid must be \"auto\" or \"RxC\" (e.g. \"2x4\"), got {s:?}"));
        let (r, c) = s.split_once('x').ok_or_else(bad)?;
        let p_r: u32 = r.parse().map_err(|_| bad())?;
        let p_c: u32 = c.parse().map_err(|_| bad())?;
        if p_r == 0 || p_c == 0 {
            return Err(bad());
        }
        Ok(GridSpec::Fixed(p_r, p_c))
    }

    /// Concrete grid for a `p`-rank mesh. `Fixed` shapes must tile the
    /// mesh exactly — a mismatch is a shape error, not a silent fallback.
    pub fn resolve(&self, p: u32) -> Result<Grid> {
        match *self {
            GridSpec::Auto => {
                if p == 0 {
                    return Err(Error::Shape("grid over an empty mesh".into()));
                }
                Ok(Grid::auto(p))
            }
            GridSpec::Fixed(p_r, p_c) => {
                if p_r as u64 * p_c as u64 != p as u64 {
                    return Err(Error::Shape(format!(
                        "grid {p_r}x{p_c} needs {} ranks, mesh has {p}",
                        p_r as u64 * p_c as u64
                    )));
                }
                Grid::new(p_r, p_c)
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            GridSpec::Auto => "auto".into(),
            GridSpec::Fixed(r, c) => format!("{r}x{c}"),
        }
    }
}

/// 2D block-cyclic distribution of a `rows` × `cols` matrix over a
/// [`Grid`] — the Elemental `[MC, MR]`-style distribution the paper's
/// routines assume. Rows are dealt to grid rows in blocks of `row_block`,
/// columns to grid columns in blocks of `col_block`, both cyclically;
/// choosing `block = ceil(extent/p)` degenerates to the pure-block
/// distribution (RowBlock is exactly the p×1 pure-block case).
///
/// Every rank in grid row i stores the same set of global rows, and every
/// rank in grid column j the same set of global columns — which is what
/// lets SUMMA broadcast A-panels along grid rows and B-panels along grid
/// columns with no per-rank reshaping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockCyclic2D {
    pub grid: Grid,
    pub rows: u64,
    pub cols: u64,
    pub row_block: u64,
    pub col_block: u64,
}

impl BlockCyclic2D {
    pub fn new(grid: Grid, rows: u64, cols: u64, row_block: u64, col_block: u64) -> Result<BlockCyclic2D> {
        if row_block == 0 || col_block == 0 {
            return Err(Error::Shape("block-cyclic blocks must be >= 1".into()));
        }
        Ok(BlockCyclic2D { grid, rows, cols, row_block, col_block })
    }

    /// Pure-block distribution: one contiguous block per grid row/column.
    pub fn blocked(grid: Grid, rows: u64, cols: u64) -> BlockCyclic2D {
        let rb = rows.div_ceil(grid.p_r as u64).max(1);
        let cb = cols.div_ceil(grid.p_c as u64).max(1);
        BlockCyclic2D { grid, rows, cols, row_block: rb, col_block: cb }
    }

    /// Grid row owning global row `i`.
    pub fn owner_row(&self, i: u64) -> u32 {
        debug_assert!(i < self.rows);
        ((i / self.row_block) % self.grid.p_r as u64) as u32
    }

    /// Grid column owning global column `j`.
    pub fn owner_col(&self, j: u64) -> u32 {
        debug_assert!(j < self.cols);
        ((j / self.col_block) % self.grid.p_c as u64) as u32
    }

    /// Mesh rank storing element (i, j).
    pub fn owner(&self, i: u64, j: u64) -> u32 {
        self.grid.rank_of(self.owner_row(i), self.owner_col(j))
    }

    /// Local row index of global row `i` on its owning grid row.
    pub fn local_row(&self, i: u64) -> u64 {
        let (b, q) = (self.row_block, self.grid.p_r as u64);
        (i / (b * q)) * b + i % b
    }

    /// Local column index of global column `j` on its owning grid column.
    pub fn local_col(&self, j: u64) -> u64 {
        let (b, q) = (self.col_block, self.grid.p_c as u64);
        (j / (b * q)) * b + j % b
    }

    /// Number of global rows stored by grid row `gr`.
    pub fn local_rows(&self, gr: u32) -> u64 {
        cyclic_count(self.rows, self.row_block, self.grid.p_r, gr)
    }

    /// Number of global columns stored by grid column `gc`.
    pub fn local_cols(&self, gc: u32) -> u64 {
        cyclic_count(self.cols, self.col_block, self.grid.p_c, gc)
    }

    /// Global row of local row `li` on grid row `gr` (inverse of
    /// `local_row` restricted to `gr`).
    pub fn global_row(&self, gr: u32, li: u64) -> u64 {
        let b = self.row_block;
        (li / b * self.grid.p_r as u64 + gr as u64) * b + li % b
    }

    /// Global column of local column `lj` on grid column `gc`.
    pub fn global_col(&self, gc: u32, lj: u64) -> u64 {
        let b = self.col_block;
        (lj / b * self.grid.p_c as u64 + gc as u64) * b + lj % b
    }

    /// The `(global_start, width)` column blocks owned by grid column
    /// `gc`, in local order (each block is contiguous both globally and
    /// locally — the unit the redistribution kernels copy).
    pub fn col_blocks_of(&self, gc: u32) -> impl Iterator<Item = (u64, u64)> + '_ {
        let nb = self.cols.div_ceil(self.col_block);
        let (b, q) = (self.col_block, self.grid.p_c as u64);
        (0..nb).filter(move |t| t % q == gc as u64).map(move |t| {
            let j0 = t * b;
            (j0, b.min(self.cols - j0))
        })
    }

    /// As [`Self::col_blocks_of`], for the row dimension.
    pub fn row_blocks_of(&self, gr: u32) -> impl Iterator<Item = (u64, u64)> + '_ {
        let nb = self.rows.div_ceil(self.row_block);
        let (b, q) = (self.row_block, self.grid.p_r as u64);
        (0..nb).filter(move |t| t % q == gr as u64).map(move |t| {
            let i0 = t * b;
            (i0, b.min(self.rows - i0))
        })
    }
}

/// Elements stored by cyclic slot `s` when `extent` indices are dealt in
/// blocks of `b` over `q` slots: full blocks except (possibly) the
/// globally-last one.
fn cyclic_count(extent: u64, b: u64, q: u32, s: u32) -> u64 {
    debug_assert!(s < q);
    if extent == 0 {
        return 0;
    }
    let nb = extent.div_ceil(b);
    let q = q as u64;
    let owned = nb / q + u64::from(nb % q > s as u64);
    let mut count = owned * b;
    if (nb - 1) % q == s as u64 {
        count -= nb * b - extent; // last block is short by this much
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layouts() -> Vec<Layout> {
        let mut out = Vec::new();
        for kind in [LayoutKind::RowBlock, LayoutKind::RowCyclic] {
            for rows in [1u64, 5, 16, 17, 100] {
                for slots in [1u32, 2, 3, 7, 16] {
                    out.push(Layout::new(kind, rows, slots).unwrap());
                }
            }
        }
        out
    }

    #[test]
    fn every_row_has_exactly_one_owner_and_maps_invert() {
        for l in layouts() {
            let mut seen = vec![false; l.rows as usize];
            for slot in 0..l.slots {
                for (li, r) in l.rows_of_slot(slot).enumerate() {
                    assert!(r < l.rows, "{l:?}");
                    assert!(!seen[r as usize], "row {r} double-owned in {l:?}");
                    seen[r as usize] = true;
                    assert_eq!(l.owner_slot(r), slot, "{l:?}");
                    assert_eq!(l.local_index(r), li as u64, "{l:?}");
                    assert_eq!(l.global_index(slot, li as u64), r, "{l:?}");
                }
            }
            assert!(seen.iter().all(|&s| s), "rows unowned in {l:?}");
        }
    }

    #[test]
    fn counts_sum_to_rows() {
        for l in layouts() {
            let total: u64 = (0..l.slots).map(|s| l.local_count(s)).sum();
            assert_eq!(total, l.rows, "{l:?}");
        }
    }

    #[test]
    fn row_block_is_contiguous() {
        let l = Layout::new(LayoutKind::RowBlock, 10, 3).unwrap();
        // block = ceil(10/3) = 4 -> slots own [0..4), [4..8), [8..10)
        assert_eq!(l.rows_of_slot(0).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(l.rows_of_slot(1).collect::<Vec<_>>(), vec![4, 5, 6, 7]);
        assert_eq!(l.rows_of_slot(2).collect::<Vec<_>>(), vec![8, 9]);
    }

    #[test]
    fn row_cyclic_interleaves() {
        let l = Layout::new(LayoutKind::RowCyclic, 7, 3).unwrap();
        assert_eq!(l.rows_of_slot(0).collect::<Vec<_>>(), vec![0, 3, 6]);
        assert_eq!(l.rows_of_slot(1).collect::<Vec<_>>(), vec![1, 4]);
        assert_eq!(l.rows_of_slot(2).collect::<Vec<_>>(), vec![2, 5]);
    }

    #[test]
    fn zero_slots_rejected() {
        assert!(Layout::new(LayoutKind::RowBlock, 10, 0).is_err());
    }

    #[test]
    fn replicated_every_slot_stores_every_row() {
        let l = Layout::new(LayoutKind::Replicated, 7, 3).unwrap();
        for slot in 0..3 {
            assert_eq!(l.local_count(slot), 7);
            assert_eq!(l.rows_of_slot(slot).collect::<Vec<_>>(), (0..7).collect::<Vec<_>>());
            for r in 0..7 {
                assert!(l.owns(slot, r));
                assert_eq!(l.local_index(r), r);
                assert_eq!(l.global_index(slot, r), r);
            }
        }
        // The canonical fetch owner is slot 0.
        for r in 0..7 {
            assert_eq!(l.owner_slot(r), 0);
        }
        // Non-replicated layouts keep exclusive ownership semantics.
        let rb = Layout::new(LayoutKind::RowBlock, 10, 2).unwrap();
        assert!(rb.owns(0, 2) && !rb.owns(1, 2));
    }

    #[test]
    fn grid_auto_is_most_square() {
        assert_eq!(Grid::auto(1), Grid { p_r: 1, p_c: 1 });
        assert_eq!(Grid::auto(4), Grid { p_r: 2, p_c: 2 });
        assert_eq!(Grid::auto(6), Grid { p_r: 3, p_c: 2 });
        assert_eq!(Grid::auto(12), Grid { p_r: 4, p_c: 3 });
        assert_eq!(Grid::auto(36), Grid { p_r: 6, p_c: 6 });
        // primes fall back to the 1D ring shape
        assert_eq!(Grid::auto(7), Grid { p_r: 7, p_c: 1 });
        assert_eq!(Grid::auto(13), Grid { p_r: 13, p_c: 1 });
    }

    #[test]
    fn grid_rank_maps_invert() {
        for (p_r, p_c) in [(1u32, 1u32), (1, 5), (5, 1), (2, 3), (4, 4)] {
            let g = Grid::new(p_r, p_c).unwrap();
            let mut seen = vec![false; g.size() as usize];
            for r in 0..p_r {
                for c in 0..p_c {
                    let rank = g.rank_of(r, c);
                    assert!(rank < g.size());
                    assert!(!seen[rank as usize], "rank {rank} double-assigned");
                    seen[rank as usize] = true;
                    assert_eq!(g.row_of(rank), r);
                    assert_eq!(g.col_of(rank), c);
                }
            }
        }
        assert!(Grid::new(0, 3).is_err());
    }

    #[test]
    fn grid_spec_parses_and_resolves() {
        assert_eq!(GridSpec::parse("auto").unwrap(), GridSpec::Auto);
        assert_eq!(GridSpec::parse("2x3").unwrap(), GridSpec::Fixed(2, 3));
        assert!(GridSpec::parse("2x").is_err());
        assert!(GridSpec::parse("x3").is_err());
        assert!(GridSpec::parse("0x3").is_err());
        assert!(GridSpec::parse("2*3").is_err());
        assert!(GridSpec::parse("").is_err());
        assert_eq!(GridSpec::Auto.resolve(6).unwrap(), Grid { p_r: 3, p_c: 2 });
        assert_eq!(GridSpec::Fixed(2, 3).resolve(6).unwrap(), Grid { p_r: 2, p_c: 3 });
        assert!(GridSpec::Fixed(2, 3).resolve(4).is_err());
        assert_eq!(GridSpec::Fixed(4, 2).name(), "4x2");
        assert_eq!(GridSpec::default().name(), "auto");
    }

    fn dists_2d() -> Vec<BlockCyclic2D> {
        let mut out = Vec::new();
        for (p_r, p_c) in [(1u32, 1u32), (2, 2), (3, 2), (1, 4), (4, 1)] {
            let g = Grid::new(p_r, p_c).unwrap();
            for (rows, cols) in [(1u64, 1u64), (7, 5), (16, 16), (5, 13)] {
                out.push(BlockCyclic2D::blocked(g, rows, cols));
                out.push(BlockCyclic2D::new(g, rows, cols, 2, 3).unwrap());
                out.push(BlockCyclic2D::new(g, rows, cols, 1, 1).unwrap());
            }
        }
        out
    }

    #[test]
    fn block_cyclic_2d_partitions_and_maps_invert() {
        for d in dists_2d() {
            // every element owned exactly once, local/global maps invert
            let mut owned = vec![0u32; (d.rows * d.cols) as usize];
            for gr in 0..d.grid.p_r {
                for li in 0..d.local_rows(gr) {
                    let i = d.global_row(gr, li);
                    assert!(i < d.rows, "{d:?}");
                    assert_eq!(d.owner_row(i), gr, "{d:?}");
                    assert_eq!(d.local_row(i), li, "{d:?}");
                }
            }
            for gc in 0..d.grid.p_c {
                for lj in 0..d.local_cols(gc) {
                    let j = d.global_col(gc, lj);
                    assert!(j < d.cols, "{d:?}");
                    assert_eq!(d.owner_col(j), gc, "{d:?}");
                    assert_eq!(d.local_col(j), lj, "{d:?}");
                }
            }
            for i in 0..d.rows {
                for j in 0..d.cols {
                    owned[(i * d.cols + j) as usize] += 1;
                    assert!(d.owner(i, j) < d.grid.size(), "{d:?}");
                }
            }
            assert!(owned.iter().all(|&c| c == 1));
            // counts tile the matrix
            let row_total: u64 = (0..d.grid.p_r).map(|gr| d.local_rows(gr)).sum();
            let col_total: u64 = (0..d.grid.p_c).map(|gc| d.local_cols(gc)).sum();
            assert_eq!(row_total, d.rows, "{d:?}");
            assert_eq!(col_total, d.cols, "{d:?}");
        }
    }

    #[test]
    fn block_cyclic_2d_blocks_enumerate_owned_indices() {
        for d in dists_2d() {
            for gc in 0..d.grid.p_c {
                let mut lj = 0u64;
                for (j0, w) in d.col_blocks_of(gc) {
                    assert!(w >= 1 && j0 + w <= d.cols, "{d:?}");
                    for off in 0..w {
                        assert_eq!(d.owner_col(j0 + off), gc, "{d:?}");
                        assert_eq!(d.local_col(j0 + off), lj + off, "{d:?}");
                    }
                    lj += w;
                }
                assert_eq!(lj, d.local_cols(gc), "{d:?}");
            }
            for gr in 0..d.grid.p_r {
                let total: u64 = d.row_blocks_of(gr).map(|(_, h)| h).sum();
                assert_eq!(total, d.local_rows(gr), "{d:?}");
            }
        }
    }

    #[test]
    fn pure_block_2d_matches_row_block_on_px1() {
        // RowBlock over p ranks == the p×1 pure-block 2D distribution.
        let p = 3u32;
        let l = Layout::new(LayoutKind::RowBlock, 10, p).unwrap();
        let d = BlockCyclic2D::blocked(Grid::new(p, 1).unwrap(), 10, 4);
        for i in 0..10u64 {
            assert_eq!(d.owner_row(i), l.owner_slot(i));
            assert_eq!(d.local_row(i), l.local_index(i));
        }
        for s in 0..p {
            assert_eq!(d.local_rows(s), l.local_count(s));
        }
    }
}
