//! Row-distribution math shared by the client (routing rows to workers on
//! send), the workers (local storage addressing) and the redistribution
//! kernels. Pure functions of (`LayoutKind`, total rows, #owners) — the
//! proptest suite checks the partition-function invariants (every row has
//! exactly one owner slot; local/global maps are inverse bijections).

use crate::protocol::{LayoutDesc, LayoutKind};
use crate::{Error, Result};

/// Concrete layout of `rows` matrix rows over `slots` owner slots.
/// A *slot* is an index into `LayoutDesc::owners`; the worker id living in
/// that slot is a server-side concern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Layout {
    pub kind: LayoutKind,
    pub rows: u64,
    pub slots: u32,
}

impl Layout {
    pub fn new(kind: LayoutKind, rows: u64, slots: u32) -> Result<Layout> {
        if slots == 0 {
            return Err(Error::Shape("layout needs >= 1 slot".into()));
        }
        Ok(Layout { kind, rows, slots })
    }

    pub fn from_desc(desc: &LayoutDesc, rows: u64) -> Result<Layout> {
        Layout::new(desc.kind, rows, desc.owners.len() as u32)
    }

    /// Rows per block in the RowBlock layout.
    fn block(&self) -> u64 {
        let p = self.slots as u64;
        (self.rows + p - 1) / p
    }

    /// Which slot owns global row `r`. For `Replicated` layouts every
    /// slot stores every row; this returns the *canonical* owner (slot 0)
    /// — the one fetches should read from. Use [`Layout::owns`] for
    /// storage-membership checks.
    pub fn owner_slot(&self, r: u64) -> u32 {
        debug_assert!(r < self.rows);
        match self.kind {
            LayoutKind::RowBlock => {
                let b = self.block().max(1);
                ((r / b).min(self.slots as u64 - 1)) as u32
            }
            LayoutKind::RowCyclic => (r % self.slots as u64) as u32,
            LayoutKind::Replicated => 0,
        }
    }

    /// True when `slot` stores global row `r` (every slot, for
    /// `Replicated`; exactly the owner slot otherwise).
    pub fn owns(&self, slot: u32, r: u64) -> bool {
        match self.kind {
            LayoutKind::Replicated => slot < self.slots,
            _ => self.owner_slot(r) == slot,
        }
    }

    /// Local row index of global row `r` within its owner's panel.
    pub fn local_index(&self, r: u64) -> u64 {
        match self.kind {
            LayoutKind::RowBlock => r - self.owner_slot(r) as u64 * self.block().max(1),
            LayoutKind::RowCyclic => r / self.slots as u64,
            LayoutKind::Replicated => r,
        }
    }

    /// Number of rows stored by `slot`.
    pub fn local_count(&self, slot: u32) -> u64 {
        debug_assert!(slot < self.slots);
        match self.kind {
            LayoutKind::RowBlock => {
                let b = self.block();
                let start = (slot as u64 * b).min(self.rows);
                let end = ((slot as u64 + 1) * b).min(self.rows);
                end - start
            }
            LayoutKind::RowCyclic => {
                let p = self.slots as u64;
                let s = slot as u64;
                if s < self.rows % p {
                    self.rows / p + 1
                } else {
                    self.rows / p
                }
            }
            LayoutKind::Replicated => self.rows,
        }
    }

    /// Global row index of local row `li` on `slot` (inverse of
    /// `local_index` restricted to the slot).
    pub fn global_index(&self, slot: u32, li: u64) -> u64 {
        match self.kind {
            LayoutKind::RowBlock => slot as u64 * self.block() + li,
            LayoutKind::RowCyclic => li * self.slots as u64 + slot as u64,
            LayoutKind::Replicated => li,
        }
    }

    /// Iterator over the global rows owned by `slot`, in local order.
    pub fn rows_of_slot(&self, slot: u32) -> impl Iterator<Item = u64> + '_ {
        let count = self.local_count(slot);
        (0..count).map(move |li| self.global_index(slot, li))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layouts() -> Vec<Layout> {
        let mut out = Vec::new();
        for kind in [LayoutKind::RowBlock, LayoutKind::RowCyclic] {
            for rows in [1u64, 5, 16, 17, 100] {
                for slots in [1u32, 2, 3, 7, 16] {
                    out.push(Layout::new(kind, rows, slots).unwrap());
                }
            }
        }
        out
    }

    #[test]
    fn every_row_has_exactly_one_owner_and_maps_invert() {
        for l in layouts() {
            let mut seen = vec![false; l.rows as usize];
            for slot in 0..l.slots {
                for (li, r) in l.rows_of_slot(slot).enumerate() {
                    assert!(r < l.rows, "{l:?}");
                    assert!(!seen[r as usize], "row {r} double-owned in {l:?}");
                    seen[r as usize] = true;
                    assert_eq!(l.owner_slot(r), slot, "{l:?}");
                    assert_eq!(l.local_index(r), li as u64, "{l:?}");
                    assert_eq!(l.global_index(slot, li as u64), r, "{l:?}");
                }
            }
            assert!(seen.iter().all(|&s| s), "rows unowned in {l:?}");
        }
    }

    #[test]
    fn counts_sum_to_rows() {
        for l in layouts() {
            let total: u64 = (0..l.slots).map(|s| l.local_count(s)).sum();
            assert_eq!(total, l.rows, "{l:?}");
        }
    }

    #[test]
    fn row_block_is_contiguous() {
        let l = Layout::new(LayoutKind::RowBlock, 10, 3).unwrap();
        // block = ceil(10/3) = 4 -> slots own [0..4), [4..8), [8..10)
        assert_eq!(l.rows_of_slot(0).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(l.rows_of_slot(1).collect::<Vec<_>>(), vec![4, 5, 6, 7]);
        assert_eq!(l.rows_of_slot(2).collect::<Vec<_>>(), vec![8, 9]);
    }

    #[test]
    fn row_cyclic_interleaves() {
        let l = Layout::new(LayoutKind::RowCyclic, 7, 3).unwrap();
        assert_eq!(l.rows_of_slot(0).collect::<Vec<_>>(), vec![0, 3, 6]);
        assert_eq!(l.rows_of_slot(1).collect::<Vec<_>>(), vec![1, 4]);
        assert_eq!(l.rows_of_slot(2).collect::<Vec<_>>(), vec![2, 5]);
    }

    #[test]
    fn zero_slots_rejected() {
        assert!(Layout::new(LayoutKind::RowBlock, 10, 0).is_err());
    }

    #[test]
    fn replicated_every_slot_stores_every_row() {
        let l = Layout::new(LayoutKind::Replicated, 7, 3).unwrap();
        for slot in 0..3 {
            assert_eq!(l.local_count(slot), 7);
            assert_eq!(l.rows_of_slot(slot).collect::<Vec<_>>(), (0..7).collect::<Vec<_>>());
            for r in 0..7 {
                assert!(l.owns(slot, r));
                assert_eq!(l.local_index(r), r);
                assert_eq!(l.global_index(slot, r), r);
            }
        }
        // The canonical fetch owner is slot 0.
        for r in 0..7 {
            assert_eq!(l.owner_slot(r), 0);
        }
        // Non-replicated layouts keep exclusive ownership semantics.
        let rb = Layout::new(LayoutKind::RowBlock, 10, 2).unwrap();
        assert!(rb.owns(0, 2) && !rb.owns(1, 2));
    }
}
