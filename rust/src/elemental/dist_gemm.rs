//! Distributed GEMM — the Elemental `Gemm` substitute that Alchemist wraps
//! for the Table 1 experiment.
//!
//! Decomposition (1D, panel-replicated): A (m x k) and C (m x n) are
//! row-distributed; B (k x n) is all-gathered so every worker holds it,
//! then each worker computes its C panel with a *local* GEMM:
//!
//! ```text
//!   C_local = A_local · B         (one call per worker, no further comm)
//! ```
//!
//! The local GEMM goes through a pluggable [`GemmBackend`] — the PJRT
//! Pallas-tile path in production (`runtime::PjrtBackend`), the native
//! blocked kernel as fallback/ablation.

use crate::comm::{collectives, Mesh};
use crate::elemental::LocalPanel;
use crate::linalg::DenseMatrix;
use crate::protocol::{LayoutDesc, LayoutKind, MatrixMeta};
use crate::{Error, Result};

/// Node-local GEMM provider. `c = a @ b` with `c` pre-zeroed by callers
/// that want plain multiply.
pub trait GemmBackend: Send + Sync {
    fn gemm_acc(&self, a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()>;

    fn gemm(&self, a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
        let mut c = DenseMatrix::zeros(a.rows(), b.cols());
        self.gemm_acc(a, b, &mut c)?;
        Ok(c)
    }

    /// Backend label for logs/benches.
    fn name(&self) -> &'static str;
}

/// Pure-Rust blocked GEMM backend (`linalg::gemm`).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

impl GemmBackend for NativeBackend {
    fn gemm_acc(&self, a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        crate::linalg::gemm::gemm_acc(a, b, c)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// All-gather a row-distributed matrix so every rank holds the full thing.
/// Requires RowBlock layout (panels concatenate contiguously).
pub fn allgather_matrix(mesh: &mut Mesh, panel: &LocalPanel) -> Result<DenseMatrix> {
    if panel.meta.layout.kind != LayoutKind::RowBlock {
        return Err(Error::Shape(
            "allgather_matrix requires RowBlock layout (redistribute first)".into(),
        ));
    }
    let parts = collectives::allgather(mesh, panel.local().data())?;
    let cols = panel.meta.cols as usize;
    let mut data = Vec::with_capacity(panel.meta.rows as usize * cols);
    for part in parts {
        data.extend_from_slice(&part);
    }
    DenseMatrix::from_vec(panel.meta.rows as usize, cols, data)
}

/// SPMD distributed GEMM: every session worker passes its panels of A and
/// B; returns its panel of C = A·B with C row-distributed like A.
pub fn dist_gemm(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    c_handle: u64,
    backend: &dyn GemmBackend,
) -> Result<LocalPanel> {
    if a.meta.cols != b.meta.rows {
        return Err(Error::Shape(format!(
            "dist_gemm: A is {}x{}, B is {}x{}",
            a.meta.rows, a.meta.cols, b.meta.rows, b.meta.cols
        )));
    }
    if a.meta.layout.kind != LayoutKind::RowBlock {
        return Err(Error::Shape("dist_gemm requires RowBlock A".into()));
    }
    let b_full = allgather_matrix(mesh, b)?;
    let c_local = backend.gemm(a.local(), &b_full)?;
    let c_meta = MatrixMeta {
        handle: c_handle,
        rows: a.meta.rows,
        cols: b.meta.cols,
        layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: a.meta.layout.owners.clone() },
    };
    LocalPanel::from_local(c_meta, a.slot, c_local)
}

/// Distributed Frobenius norm: local partial + scalar all-reduce.
pub fn dist_frobenius(mesh: &mut Mesh, panel: &LocalPanel) -> Result<f64> {
    let local: f64 = panel.local().data().iter().map(|x| x * x).sum();
    let mut buf = vec![local];
    collectives::allreduce_sum(mesh, &mut buf, collectives::AllReduceAlgo::Ring)?;
    Ok(buf[0].sqrt())
}

/// Distributed Gram matvec: w = Aᵀ(A v) with A row-distributed; v and w
/// are replicated length-n vectors. One ring all-reduce per application —
/// the Lanczos hot path. The local two-sided product is delegated to the
/// backend-agnostic closure `local_gram` so callers can route it through
/// PJRT (fused gram artifact) or native kernels.
pub fn dist_gram_matvec(
    mesh: &mut Mesh,
    v: &[f64],
    local_gram: impl FnOnce(&[f64]) -> Result<Vec<f64>>,
) -> Result<Vec<f64>> {
    let mut w = local_gram(v)?;
    collectives::allreduce_sum(mesh, &mut w, collectives::AllReduceAlgo::Ring)?;
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_mesh;
    use crate::elemental::panel::{gather_matrix, scatter_matrix};
    use crate::linalg::gemm::gemm;
    use crate::workload::random_matrix;
    use std::sync::Arc;

    fn meta(handle: u64, rows: u64, cols: u64, p: u32) -> MatrixMeta {
        MatrixMeta {
            handle,
            rows,
            cols,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: (0..p).collect() },
        }
    }

    #[test]
    fn dist_gemm_matches_local() {
        let (m, k, n, p) = (37u64, 11u64, 8u64, 3usize);
        let a_full = DenseMatrix::from_vec(m as usize, k as usize, random_matrix(1, m as usize, k as usize)).unwrap();
        let b_full = DenseMatrix::from_vec(k as usize, n as usize, random_matrix(2, k as usize, n as usize)).unwrap();
        let a_panels = Arc::new(scatter_matrix(&meta(1, m, k, p as u32), &a_full).unwrap());
        let b_panels = Arc::new(scatter_matrix(&meta(2, k, n, p as u32), &b_full).unwrap());
        let (ap, bp) = (a_panels.clone(), b_panels.clone());
        let c_panels = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            dist_gemm(&mut mesh, &ap[rank], &bp[rank], 3, &NativeBackend)
        })
        .unwrap();
        let c = gather_matrix(&c_panels).unwrap();
        let want = gemm(&a_full, &b_full).unwrap();
        assert!(c.max_abs_diff(&want).unwrap() < 1e-10);
        assert_eq!(c_panels[0].meta.handle, 3);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a_full = DenseMatrix::zeros(4, 3);
        let b_full = DenseMatrix::zeros(5, 2);
        let ap = Arc::new(scatter_matrix(&meta(1, 4, 3, 1), &a_full).unwrap());
        let bp = Arc::new(scatter_matrix(&meta(2, 5, 2, 1), &b_full).unwrap());
        let res = run_mesh(1, move |mut mesh| {
            match dist_gemm(&mut mesh, &ap[0], &bp[0], 3, &NativeBackend) {
                Err(crate::Error::Shape(_)) => Ok(true),
                _ => Ok(false),
            }
        })
        .unwrap();
        assert!(res[0]);
    }

    #[test]
    fn dist_frobenius_matches_local() {
        let full = DenseMatrix::from_vec(10, 4, random_matrix(5, 10, 4)).unwrap();
        let panels = Arc::new(scatter_matrix(&meta(1, 10, 4, 2), &full).unwrap());
        let want = full.frobenius_norm();
        let got = run_mesh(2, move |mut mesh| {
            let rank = mesh.rank();
            dist_frobenius(&mut mesh, &panels[rank])
        })
        .unwrap();
        for g in got {
            assert!((g - want).abs() < 1e-10);
        }
    }

    #[test]
    fn dist_gram_matvec_matches_dense() {
        let (m, n, p) = (20usize, 6usize, 2usize);
        let full = DenseMatrix::from_vec(m, n, random_matrix(7, m, n)).unwrap();
        let panels = Arc::new(scatter_matrix(&meta(1, m as u64, n as u64, p as u32), &full).unwrap());
        let v: Vec<f64> = (0..n).map(|i| (i + 1) as f64).collect();
        let v2 = v.clone();
        let got = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            let panel = &panels[rank];
            dist_gram_matvec(&mut mesh, &v2, |x| {
                let t = panel.local().matvec(x)?;
                panel.local().matvec_t(&t)
            })
        })
        .unwrap();
        // dense reference: w = Aᵀ A v
        let t = full.matvec(&v).unwrap();
        let want = full.matvec_t(&t).unwrap();
        for g in got {
            for (a, b) in g.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }
}
