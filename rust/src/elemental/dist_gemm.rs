//! Distributed GEMM — the Elemental `Gemm` substitute that Alchemist wraps
//! for the Table 1 experiment.
//!
//! Decomposition (1D over rows): A (m x k) and C (m x n) are
//! row-distributed; B (k x n) is row-distributed in RowBlock panels.
//! Two algorithms, selected by [`DistGemmAlgo`]:
//!
//! * **RingPipelined** (default) — 1D SUMMA variant: B's row-panels
//!   rotate around the ring while every rank accumulates
//!   `C_local += A_local[:, k_o..] · B_panel(o)` with the pluggable
//!   [`GemmBackend`]. A dedicated sender/receiver thread pair per rank
//!   ([`collectives::RingPipeline`]) overlaps the shift of the next panel
//!   with compute on the current one; after the first panel the
//!   communication hides behind compute. Peak extra B memory per rank is
//!   **two panels** (≤ 2·ceil(k/p)·n doubles, asserted by the prop suite
//!   through [`dist_gemm_ring_with_stats`]); the full B is never
//!   materialized anywhere.
//!
//! * **AllGatherB** — the legacy baseline: all-gather the whole B onto
//!   every rank (O(k·n) memory, all communication up front), then run the
//!   *same* panel-by-panel local schedule. Because both algorithms feed
//!   the backend identical (A-slice, B-panel, C) calls in identical
//!   order, their outputs are **bit-identical** — the ablation
//!   (`table1_matmul`, `ablate_gemm_backend`) measures pure
//!   communication/overlap effects.
//!
//! Per-rank compute vs shift-wait time and the peak panel footprint are
//! recorded in [`crate::metrics::compute_metrics`].

use std::sync::Arc;

use crate::ali::task::CancelToken;
use crate::comm::{collectives, Mesh};
use crate::elemental::{Layout, LocalPanel};
use crate::linalg::DenseMatrix;
use crate::metrics::{compute_metrics, Timer};
use crate::protocol::{LayoutDesc, LayoutKind, MatrixMeta};
use crate::{Error, Result};

/// Node-local GEMM provider. `c = a @ b` with `c` pre-zeroed by callers
/// that want plain multiply.
pub trait GemmBackend: Send + Sync {
    fn gemm_acc(&self, a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()>;

    fn gemm(&self, a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
        let mut c = DenseMatrix::zeros(a.rows(), b.cols());
        self.gemm_acc(a, b, &mut c)?;
        Ok(c)
    }

    /// Backend label for logs/benches.
    fn name(&self) -> &'static str;
}

/// Pure-Rust blocked GEMM backend (`linalg::gemm`).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

impl GemmBackend for NativeBackend {
    fn gemm_acc(&self, a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        crate::linalg::gemm::gemm_acc(a, b, c)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Which distributed-GEMM algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DistGemmAlgo {
    /// Materialize full B on every rank, then sweep panels locally.
    AllGatherB,
    /// Rotate B row-panels around the ring, overlapping shift and
    /// compute (the default).
    #[default]
    RingPipelined,
}

impl DistGemmAlgo {
    /// Parse the config / routine-param spelling ("ring" | "allgather").
    pub fn parse(s: &str) -> Result<DistGemmAlgo> {
        match s {
            "ring" => Ok(DistGemmAlgo::RingPipelined),
            "allgather" => Ok(DistGemmAlgo::AllGatherB),
            other => Err(Error::Config(format!(
                "dist_gemm algo must be ring|allgather, got {other:?}"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DistGemmAlgo::AllGatherB => "allgather",
            DistGemmAlgo::RingPipelined => "ring",
        }
    }
}

/// Tunables for [`dist_gemm_with`] (the `[compute]` config section).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DistGemmOptions {
    pub algo: DistGemmAlgo,
    /// Split each owned B panel into sub-panels of at most this many rows
    /// before shifting (finer pipelining granularity); 0 = shift whole
    /// owned panels (the default, and the 2-panel memory contract).
    pub panel_rows: usize,
}

/// Per-call observability from the ring path (test hook + metrics feed).
#[derive(Debug, Clone, Copy, Default)]
pub struct RingStats {
    /// High-water mark of B-panel doubles resident on this rank
    /// (compute panel + receiver prefetch + any not-yet-retired
    /// in-flight send).
    pub peak_b_doubles: usize,
    /// Time inside the local GEMM kernel.
    pub compute_s: f64,
    /// Time stalled on the pipeline (enqueueing sends + awaiting recvs).
    pub wait_s: f64,
    /// Panels shifted through this rank.
    pub shifts: usize,
}

/// All-gather a row-distributed matrix so every rank holds the full thing.
/// Requires RowBlock layout (panels concatenate contiguously). Gathers
/// straight into one flat pre-sized buffer (`collectives::allgather_flat`)
/// — no per-rank `Vec` staging, no re-concatenation copy.
pub fn allgather_matrix(mesh: &mut Mesh, panel: &LocalPanel) -> Result<DenseMatrix> {
    if panel.meta.layout.kind != LayoutKind::RowBlock {
        return Err(Error::Shape(
            "allgather_matrix requires RowBlock layout (redistribute first)".into(),
        ));
    }
    let layout = panel.layout();
    let cols = panel.meta.cols as usize;
    let counts: Vec<usize> =
        (0..layout.slots).map(|s| layout.local_count(s) as usize * cols).collect();
    let flat = collectives::allgather_flat(mesh, panel.local().data(), &counts)?;
    DenseMatrix::from_vec(panel.meta.rows as usize, cols, flat)
}

/// SPMD distributed GEMM with the default options (ring-pipelined, whole
/// owned panels): every session worker passes its panels of A and B;
/// returns its panel of C = A·B with C row-distributed like A.
pub fn dist_gemm(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    c_handle: u64,
    backend: &dyn GemmBackend,
) -> Result<LocalPanel> {
    dist_gemm_with(mesh, a, b, c_handle, backend, &DistGemmOptions::default())
}

/// SPMD distributed GEMM with explicit algorithm/panel options.
pub fn dist_gemm_with(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    c_handle: u64,
    backend: &dyn GemmBackend,
    opts: &DistGemmOptions,
) -> Result<LocalPanel> {
    dist_gemm_with_cancel(mesh, a, b, c_handle, backend, opts, None)
}

/// [`dist_gemm_with`] plus a cooperative cancel token, checked at
/// panel-step boundaries. Cancellation preserves the collective protocol:
/// a flagged rank keeps shifting/forwarding panels (skipping only the
/// local compute) and all ranks agree on the flag in one scalar
/// all-reduce after the panel sweep, so either every rank returns
/// [`Error::Cancelled`] or none does — the mesh is never left desynced.
pub fn dist_gemm_with_cancel(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    c_handle: u64,
    backend: &dyn GemmBackend,
    opts: &DistGemmOptions,
    cancel: Option<&CancelToken>,
) -> Result<LocalPanel> {
    validate_operands(mesh, a, b)?;
    let rank = mesh.rank();
    let m = compute_metrics();
    let c_local = match opts.algo {
        DistGemmAlgo::AllGatherB => {
            m.allgather_gemms.inc(1);
            dist_gemm_allgather_local(mesh, a, b, backend, opts.panel_rows, cancel)?
        }
        DistGemmAlgo::RingPipelined => {
            m.ring_gemms.inc(1);
            let (c_local, stats) =
                dist_gemm_ring_local(mesh, a, b, backend, opts.panel_rows, cancel)?;
            m.phases.add(
                &format!("ring_compute_r{rank}"),
                std::time::Duration::from_secs_f64(stats.compute_s),
            );
            m.phases.add(
                &format!("ring_wait_r{rank}"),
                std::time::Duration::from_secs_f64(stats.wait_s),
            );
            m.peak_b_doubles.set_max(stats.peak_b_doubles as i64);
            c_local
        }
    };
    wrap_output(a, b, c_handle, c_local)
}

/// Ring-pipelined distributed GEMM returning the per-rank [`RingStats`] —
/// the prop suite asserts the two-panel memory contract through this.
pub fn dist_gemm_ring_with_stats(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    c_handle: u64,
    backend: &dyn GemmBackend,
    panel_rows: usize,
) -> Result<(LocalPanel, RingStats)> {
    validate_operands(mesh, a, b)?;
    let (c_local, stats) = dist_gemm_ring_local(mesh, a, b, backend, panel_rows, None)?;
    Ok((wrap_output(a, b, c_handle, c_local)?, stats))
}

fn validate_operands(mesh: &Mesh, a: &LocalPanel, b: &LocalPanel) -> Result<()> {
    if a.meta.cols != b.meta.rows {
        return Err(Error::Shape(format!(
            "dist_gemm: A is {}x{}, B is {}x{}",
            a.meta.rows, a.meta.cols, b.meta.rows, b.meta.cols
        )));
    }
    if a.meta.layout.kind != LayoutKind::RowBlock {
        return Err(Error::Shape("dist_gemm requires RowBlock A".into()));
    }
    if b.meta.layout.kind != LayoutKind::RowBlock {
        return Err(Error::Shape("dist_gemm requires RowBlock B".into()));
    }
    let p = mesh.size() as u32;
    if a.layout().slots != p || b.layout().slots != p {
        return Err(Error::Shape(format!(
            "dist_gemm: A has {} owners, B has {}, mesh has {p} ranks",
            a.layout().slots,
            b.layout().slots
        )));
    }
    let rank = mesh.rank() as u32;
    if a.slot != rank || b.slot != rank {
        return Err(Error::Shape(format!(
            "dist_gemm: rank {rank} holds A slot {} / B slot {} (slots must follow mesh ranks)",
            a.slot, b.slot
        )));
    }
    Ok(())
}

fn wrap_output(a: &LocalPanel, b: &LocalPanel, c_handle: u64, c_local: DenseMatrix) -> Result<LocalPanel> {
    let c_meta = MatrixMeta {
        handle: c_handle,
        rows: a.meta.rows,
        cols: b.meta.cols,
        layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: a.meta.layout.owners.clone() },
    };
    LocalPanel::from_local(c_meta, a.slot, c_local)
}

/// Contiguous global k-ranges `(k0, rows)` of `origin`'s owned B rows,
/// split into chunks of at most `panel_rows` rows (0 = one chunk).
fn sub_panels(layout: &Layout, origin: u32, panel_rows: usize) -> Vec<(u64, usize)> {
    let count = layout.local_count(origin) as usize;
    if count == 0 {
        return Vec::new();
    }
    let start = layout.global_index(origin, 0);
    let w = if panel_rows == 0 { count } else { panel_rows };
    let mut out = Vec::with_capacity((count + w - 1) / w);
    let mut off = 0usize;
    while off < count {
        let rows = w.min(count - off);
        out.push((start + off as u64, rows));
        off += rows;
    }
    out
}

/// `C_local += A_local[:, k0..k0+panel.rows()] · panel`. The one place
/// both algorithms call the backend — identical calls in identical order
/// is what makes ring and allgather outputs bit-identical.
///
/// The A column slice is materialized with `block_padded` (one extra
/// copy of A_local per dist_gemm call, amortized over the panels). This
/// is deliberate: the pluggable backend takes whole `DenseMatrix`
/// operands (the PJRT path uploads them as-is), and the copy is
/// O(m·k) against the call's O(m·k·n) FLOPs — noise for any n beyond a
/// few columns. Fusing the slice into `pack_a` would save it for the
/// native backend only, at the cost of a second backend entry point.
fn accumulate_panel(
    backend: &dyn GemmBackend,
    a_local: &DenseMatrix,
    k0: usize,
    panel: &DenseMatrix,
    c: &mut DenseMatrix,
) -> Result<()> {
    if panel.rows() == 0 {
        return Ok(());
    }
    let a_cols = a_local.block_padded(0, k0, a_local.rows(), panel.rows());
    backend.gemm_acc(&a_cols, panel, c)
}

/// Legacy baseline: materialize full B, then run the identical cyclic
/// panel schedule the ring uses.
fn dist_gemm_allgather_local(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    backend: &dyn GemmBackend,
    panel_rows: usize,
    cancel: Option<&CancelToken>,
) -> Result<DenseMatrix> {
    let b_full = allgather_matrix(mesh, b)?;
    let p = mesh.size();
    let rank = mesh.rank();
    let layout_b = b.layout();
    let n = b.meta.cols as usize;
    let mut c = DenseMatrix::zeros(a.local_rows(), n);
    for d in 0..p {
        let origin = ((rank + d) % p) as u32;
        for (k0, rows) in sub_panels(&layout_b, origin, panel_rows) {
            // Cancelled ranks skip the compute only; the flag is agreed
            // collectively below before anyone returns.
            if cancel.is_some_and(|t| t.is_cancelled()) {
                continue;
            }
            let panel = b_full.block_padded(k0 as usize, 0, rows, n);
            accumulate_panel(backend, a.local(), k0 as usize, &panel, &mut c)?;
        }
    }
    agree_not_cancelled(mesh, cancel, "gemm (allgather)")?;
    Ok(c)
}

/// Collective cancel agreement after a panel sweep: every rank returns
/// `Err(Cancelled)` iff any rank's token was set. No-op without a token
/// (plain `dist_gemm_with` calls stay bitwise-identical to before).
fn agree_not_cancelled(
    mesh: &mut Mesh,
    cancel: Option<&CancelToken>,
    what: &str,
) -> Result<()> {
    let Some(token) = cancel else { return Ok(()) };
    let flagged = if mesh.size() == 1 {
        token.is_cancelled()
    } else {
        collectives::allreduce_flag(mesh, token.is_cancelled())?
    };
    if flagged {
        return Err(Error::Cancelled(format!("{what} cancelled mid-panel-sweep")));
    }
    Ok(())
}

/// The ring: rank r sends panels to r-1 and receives from r+1, so the
/// panel that originated at rank o reaches rank r after (o − r) mod p
/// hops — every rank processes origins in cyclic order r, r+1, …, r−1.
/// Forwarding is handled inside [`collectives::RingPipeline`]: the wire
/// order is this rank's own panels followed by every received panel
/// except those of origin `to` (whose last recipient we are).
fn dist_gemm_ring_local(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    backend: &dyn GemmBackend,
    panel_rows: usize,
    cancel: Option<&CancelToken>,
) -> Result<(DenseMatrix, RingStats)> {
    let p = mesh.size();
    let rank = mesh.rank();
    let layout_b = b.layout();
    let n = b.meta.cols as usize;
    let mut c = DenseMatrix::zeros(a.local_rows(), n);
    let mut stats = RingStats::default();

    // Schedule: (origin, k0, rows) in compute order.
    let schedule: Vec<(u32, u64, usize)> = (0..p)
        .flat_map(|d| {
            let origin = ((rank + d) % p) as u32;
            sub_panels(&layout_b, origin, panel_rows)
                .into_iter()
                .map(move |(k0, rows)| (origin, k0, rows))
        })
        .collect();

    if p == 1 {
        let t = Timer::start();
        for &(_, k0, rows) in &schedule {
            if cancel.is_some_and(|tok| tok.is_cancelled()) {
                return Err(Error::Cancelled("gemm cancelled mid-panel-sweep".into()));
            }
            let li0 = layout_b.local_index(k0) as usize;
            let panel = DenseMatrix::from_vec(
                rows,
                n,
                b.local().data()[li0 * n..(li0 + rows) * n].to_vec(),
            )?;
            stats.peak_b_doubles = stats.peak_b_doubles.max(rows * n);
            accumulate_panel(backend, a.local(), k0 as usize, &panel, &mut c)?;
        }
        stats.compute_s = t.elapsed_secs();
        return Ok((c, stats));
    }

    let to = (rank + p - 1) % p;
    let from = (rank + 1) % p;
    let own_frames = sub_panels(&layout_b, rank as u32, panel_rows).len();
    let remote: Vec<usize> =
        schedule.iter().filter(|&&(o, _, _)| o as usize != rank).map(|&(_, _, r)| r).collect();
    let shapes: Vec<collectives::FrameShape> =
        remote.iter().map(|&rows| collectives::FrameShape::Matrix(rows, n)).collect();
    // Frames of origin `to` terminate here; everything else is forwarded.
    let forward_frames = remote.len() - sub_panels(&layout_b, to as u32, panel_rows).len();

    // Peak B residency, from the pipeline's channel discipline (see
    // RingPipeline docs): during the own-panel burst, all own copies
    // (≤ one whole panel) plus the receiver's first in-progress read
    // coexist; from then on a compute panel coexists with exactly one of
    // (previous frame draining onto the wire | next frame being read).
    let own_total: usize = schedule
        .iter()
        .filter(|&&(o, _, _)| o as usize == rank)
        .map(|&(_, _, r)| r * n)
        .sum();
    let mut peak = if remote.is_empty() { own_total } else { 0 };
    for i in 0..remote.len() {
        let prev = if i == 0 { own_total } else { remote[i - 1] * n };
        let next = remote.get(i + 1).map(|&r| r * n).unwrap_or(0);
        peak = peak.max(remote[i] * n + prev.max(next));
    }
    stats.peak_b_doubles = peak;

    let pipe = collectives::RingPipeline::new(mesh, to, from, own_frames, forward_frames, shapes)?;

    for &(origin, k0, rows) in &schedule {
        let panel: Arc<DenseMatrix> = if origin as usize == rank {
            let li0 = layout_b.local_index(k0) as usize;
            let arc = Arc::new(DenseMatrix::from_vec(
                rows,
                n,
                b.local().data()[li0 * n..(li0 + rows) * n].to_vec(),
            )?);
            let t = Timer::start();
            pipe.send_own(arc.clone())?;
            stats.wait_s += t.elapsed_secs();
            arc
        } else {
            let t = Timer::start();
            let got = pipe.recv()?; // shape-checked by the receiver
            stats.wait_s += t.elapsed_secs();
            got
        };
        stats.shifts += 1;

        // A cancelled rank must keep the ring protocol alive (send/recv
        // above still ran) — it only skips the local kernel. All ranks
        // agree on the flag after the sweep, below.
        if cancel.is_some_and(|tok| tok.is_cancelled()) {
            continue;
        }
        let t = Timer::start();
        accumulate_panel(backend, a.local(), k0 as usize, &panel, &mut c)?;
        stats.compute_s += t.elapsed_secs();
    }
    let t = Timer::start();
    pipe.finish()?;
    stats.wait_s += t.elapsed_secs();
    agree_not_cancelled(mesh, cancel, "gemm (ring)")?;
    Ok((c, stats))
}

/// Distributed Frobenius norm: local partial + scalar all-reduce.
pub fn dist_frobenius(mesh: &mut Mesh, panel: &LocalPanel) -> Result<f64> {
    let local: f64 = panel.local().data().iter().map(|x| x * x).sum();
    let mut buf = vec![local];
    collectives::allreduce_sum(mesh, &mut buf, collectives::AllReduceAlgo::Ring)?;
    Ok(buf[0].sqrt())
}

/// Distributed Gram matvec: w = Aᵀ(A v) with A row-distributed; v and w
/// are replicated length-n vectors. One ring all-reduce per application —
/// the Lanczos hot path. The local two-sided product is delegated to the
/// backend-agnostic closure `local_gram` so callers can route it through
/// PJRT (fused gram artifact) or native kernels.
pub fn dist_gram_matvec(
    mesh: &mut Mesh,
    v: &[f64],
    local_gram: impl FnOnce(&[f64]) -> Result<Vec<f64>>,
) -> Result<Vec<f64>> {
    let mut w = local_gram(v)?;
    collectives::allreduce_sum(mesh, &mut w, collectives::AllReduceAlgo::Ring)?;
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_mesh;
    use crate::elemental::panel::{gather_matrix, scatter_matrix};
    use crate::linalg::gemm::gemm;
    use crate::workload::random_matrix;

    fn meta(handle: u64, rows: u64, cols: u64, p: u32) -> MatrixMeta {
        MatrixMeta {
            handle,
            rows,
            cols,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: (0..p).collect() },
        }
    }

    fn run_dist_gemm(
        m: u64,
        k: u64,
        n: u64,
        p: usize,
        opts: DistGemmOptions,
        seed: u64,
    ) -> (DenseMatrix, DenseMatrix) {
        let a_full =
            DenseMatrix::from_vec(m as usize, k as usize, random_matrix(seed, m as usize, k as usize))
                .unwrap();
        let b_full = DenseMatrix::from_vec(
            k as usize,
            n as usize,
            random_matrix(seed + 1, k as usize, n as usize),
        )
        .unwrap();
        let a_panels = Arc::new(scatter_matrix(&meta(1, m, k, p as u32), &a_full).unwrap());
        let b_panels = Arc::new(scatter_matrix(&meta(2, k, n, p as u32), &b_full).unwrap());
        let c_panels = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            dist_gemm_with(&mut mesh, &a_panels[rank], &b_panels[rank], 3, &NativeBackend, &opts)
        })
        .unwrap();
        let c = gather_matrix(&c_panels).unwrap();
        let want = gemm(&a_full, &b_full).unwrap();
        (c, want)
    }

    #[test]
    fn dist_gemm_matches_local() {
        let (c, want) = run_dist_gemm(37, 11, 8, 3, DistGemmOptions::default(), 1);
        assert!(c.max_abs_diff(&want).unwrap() < 1e-10);
    }

    #[test]
    fn both_algorithms_match_local_across_shapes() {
        // ragged (p does not divide k), p > k, narrow sub-panels
        for (m, k, n, p, w) in [
            (20u64, 7u64, 5u64, 3usize, 0usize),
            (9, 2, 4, 4, 0), // p > k: some ranks own no B rows
            (16, 12, 6, 4, 2),
            (8, 5, 3, 1, 2), // solo mesh
        ] {
            for algo in [DistGemmAlgo::RingPipelined, DistGemmAlgo::AllGatherB] {
                let opts = DistGemmOptions { algo, panel_rows: w };
                let (c, want) = run_dist_gemm(m, k, n, p, opts, 7);
                assert!(
                    c.max_abs_diff(&want).unwrap() < 1e-10,
                    "{algo:?} m={m} k={k} n={n} p={p} w={w}"
                );
            }
        }
    }

    #[test]
    fn ring_and_allgather_are_bitwise_equal() {
        for (m, k, n, p, w) in [(21u64, 13u64, 9u64, 4usize, 0usize), (10, 6, 4, 3, 2)] {
            let (ring, _) = run_dist_gemm(
                m, k, n, p,
                DistGemmOptions { algo: DistGemmAlgo::RingPipelined, panel_rows: w },
                9,
            );
            let (agb, _) = run_dist_gemm(
                m, k, n, p,
                DistGemmOptions { algo: DistGemmAlgo::AllGatherB, panel_rows: w },
                9,
            );
            assert_eq!(ring, agb, "m={m} k={k} n={n} p={p} w={w}");
        }
    }

    #[test]
    fn ring_memory_contract_and_stats() {
        let (m, k, n, p) = (24u64, 10u64, 6u64, 3usize);
        let a_full =
            DenseMatrix::from_vec(m as usize, k as usize, random_matrix(3, m as usize, k as usize))
                .unwrap();
        let b_full =
            DenseMatrix::from_vec(k as usize, n as usize, random_matrix(4, k as usize, n as usize))
                .unwrap();
        let a_panels = Arc::new(scatter_matrix(&meta(1, m, k, p as u32), &a_full).unwrap());
        let b_panels = Arc::new(scatter_matrix(&meta(2, k, n, p as u32), &b_full).unwrap());
        let results = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            dist_gemm_ring_with_stats(
                &mut mesh,
                &a_panels[rank],
                &b_panels[rank],
                3,
                &NativeBackend,
                0,
            )
        })
        .unwrap();
        let bound = 2 * ((k as usize + p - 1) / p) * n as usize;
        for (panel, stats) in &results {
            assert!(
                stats.peak_b_doubles <= bound,
                "peak {} > 2·ceil(k/p)·n = {bound}",
                stats.peak_b_doubles
            );
            assert_eq!(stats.shifts, p, "every origin's panel visits every rank once");
            assert_eq!(panel.meta.handle, 3);
        }
        let c = gather_matrix(&results.iter().map(|(c, _)| c.clone()).collect::<Vec<_>>()).unwrap();
        let want = gemm(&a_full, &b_full).unwrap();
        assert!(c.max_abs_diff(&want).unwrap() < 1e-10);
    }

    #[test]
    fn algo_parsing() {
        assert_eq!(DistGemmAlgo::parse("ring").unwrap(), DistGemmAlgo::RingPipelined);
        assert_eq!(DistGemmAlgo::parse("allgather").unwrap(), DistGemmAlgo::AllGatherB);
        assert!(DistGemmAlgo::parse("summa3d").is_err());
        assert_eq!(DistGemmAlgo::default().name(), "ring");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a_full = DenseMatrix::zeros(4, 3);
        let b_full = DenseMatrix::zeros(5, 2);
        let ap = Arc::new(scatter_matrix(&meta(1, 4, 3, 1), &a_full).unwrap());
        let bp = Arc::new(scatter_matrix(&meta(2, 5, 2, 1), &b_full).unwrap());
        let res = run_mesh(1, move |mut mesh| {
            match dist_gemm(&mut mesh, &ap[0], &bp[0], 3, &NativeBackend) {
                Err(crate::Error::Shape(_)) => Ok(true),
                _ => Ok(false),
            }
        })
        .unwrap();
        assert!(res[0]);
    }

    #[test]
    fn empty_matrices_are_fine() {
        // k = 0 (no panels anywhere) and n = 0 (zero-width panels)
        for (m, k, n, p) in [(6u64, 0u64, 4u64, 2usize), (6, 5, 0, 2), (0, 3, 2, 2)] {
            for algo in [DistGemmAlgo::RingPipelined, DistGemmAlgo::AllGatherB] {
                let (c, want) =
                    run_dist_gemm(m, k, n, p, DistGemmOptions { algo, panel_rows: 0 }, 11);
                assert_eq!(c, want, "{algo:?} m={m} k={k} n={n}");
            }
        }
    }

    #[test]
    fn dist_frobenius_matches_local() {
        let full = DenseMatrix::from_vec(10, 4, random_matrix(5, 10, 4)).unwrap();
        let panels = Arc::new(scatter_matrix(&meta(1, 10, 4, 2), &full).unwrap());
        let want = full.frobenius_norm();
        let got = run_mesh(2, move |mut mesh| {
            let rank = mesh.rank();
            dist_frobenius(&mut mesh, &panels[rank])
        })
        .unwrap();
        for g in got {
            assert!((g - want).abs() < 1e-10);
        }
    }

    #[test]
    fn dist_gram_matvec_matches_dense() {
        let (m, n, p) = (20usize, 6usize, 2usize);
        let full = DenseMatrix::from_vec(m, n, random_matrix(7, m, n)).unwrap();
        let panels = Arc::new(scatter_matrix(&meta(1, m as u64, n as u64, p as u32), &full).unwrap());
        let v: Vec<f64> = (0..n).map(|i| (i + 1) as f64).collect();
        let v2 = v.clone();
        let got = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            let panel = &panels[rank];
            dist_gram_matvec(&mut mesh, &v2, |x| {
                let t = panel.local().matvec(x)?;
                panel.local().matvec_t(&t)
            })
        })
        .unwrap();
        // dense reference: w = Aᵀ A v
        let t = full.matvec(&v).unwrap();
        let want = full.matvec_t(&t).unwrap();
        for g in got {
            for (a, b) in g.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }
}
