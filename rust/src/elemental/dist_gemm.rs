//! Distributed GEMM — the Elemental `Gemm` substitute that Alchemist wraps
//! for the Table 1 experiment.
//!
//! A (m x k), B (k x n) and C (m x n) arrive row-distributed in RowBlock
//! panels. Three algorithms, selected by [`DistGemmAlgo`]:
//!
//! * **Summa2D** — true SUMMA over a p_r × p_c process grid
//!   ([`Grid`], `[compute] grid`): A and B are redistributed into 2D
//!   block-cyclic layouts ([`BlockCyclic2D`]) whose cyclic block width
//!   equals the k-panel width, then each step broadcasts one A
//!   column-panel along grid rows and one B row-panel along grid columns
//!   (two concurrent [`collectives::BcastPipeline`]s per rank) and
//!   accumulates `C_local += A_panel · B_panel` with the pluggable
//!   [`GemmBackend`]. Per-rank broadcast volume scales as O(1/√p) of the
//!   1D algorithms' for square grids — the reason Elemental's GEMM
//!   scales and the ablation's bytes-moved column. C is converted back
//!   to RowBlock on exit, so clients see identical layouts regardless of
//!   algorithm.
//!
//! * **RingPipelined** (default) — the p×1 degenerate case: B's
//!   row-panels travel the rank chain via one sequenced-broadcast
//!   pipeline while every rank accumulates
//!   `C_local += A_local[:, k0..] · B_panel`. Peak extra B memory per
//!   rank is **two panels** (≤ 2·ceil(k/p)·n doubles, asserted by the
//!   prop suite through [`dist_gemm_ring_with_stats`]); the full B is
//!   never materialized anywhere.
//!
//! * **AllGatherB** — the legacy baseline: all-gather the whole B onto
//!   every rank (O(k·n) memory, all communication up front), then run
//!   the same panel-by-panel local schedule.
//!
//! **Determinism contract**: every algorithm folds each C element's
//! k-terms in globally ascending k order — panel schedules walk k0
//! ascending on every rank, and [`BcastPipeline`] delivers frames in
//! schedule order. With a split-invariant backend (the native kernel's
//! documented contract: one add per k, accumulator chain unbroken across
//! panel boundaries), **all three algorithms, any grid shape, and any
//! panel width produce bit-identical C** — equal to a single-node local
//! GEMM. The prop and integration suites assert this exactly, not within
//! a tolerance.
//!
//! Per-rank compute vs communication-wait time, the peak panel
//! footprints, and the active backend/grid shape are recorded in
//! [`crate::metrics::compute_metrics`].

use std::sync::Arc;

use crate::ali::task::CancelToken;
use crate::comm::{collectives, Mesh, SubMesh};
use crate::elemental::redistribute::{grid_to_rowblock, rowblock_to_grid};
use crate::elemental::{BlockCyclic2D, Grid, GridSpec, Layout, LocalPanel};
use crate::linalg::DenseMatrix;
use crate::metrics::{backend_code, compute_metrics, Timer};
use crate::protocol::{LayoutDesc, LayoutKind, MatrixMeta};
use crate::{Error, Result};

/// Node-local GEMM provider. `c = a @ b` with `c` pre-zeroed by callers
/// that want plain multiply.
pub trait GemmBackend: Send + Sync {
    fn gemm_acc(&self, a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()>;

    fn gemm(&self, a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
        let mut c = DenseMatrix::zeros(a.rows(), b.cols());
        self.gemm_acc(a, b, &mut c)?;
        Ok(c)
    }

    /// Backend label for logs/benches.
    fn name(&self) -> &'static str;
}

/// Pure-Rust blocked GEMM backend (`linalg::gemm`).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

impl GemmBackend for NativeBackend {
    fn gemm_acc(&self, a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        crate::linalg::gemm::gemm_acc(a, b, c)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Which distributed-GEMM algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DistGemmAlgo {
    /// Materialize full B on every rank, then sweep panels locally.
    AllGatherB,
    /// Shift B row-panels along the rank chain, overlapping shift and
    /// compute (the default).
    #[default]
    RingPipelined,
    /// True 2D SUMMA on a p_r × p_c grid: dual pipelined panel
    /// broadcasts over row/column sub-meshes.
    Summa2D,
}

impl DistGemmAlgo {
    /// Parse the config / routine-param spelling
    /// ("ring" | "allgather" | "summa2d").
    pub fn parse(s: &str) -> Result<DistGemmAlgo> {
        match s {
            "ring" => Ok(DistGemmAlgo::RingPipelined),
            "allgather" => Ok(DistGemmAlgo::AllGatherB),
            "summa2d" => Ok(DistGemmAlgo::Summa2D),
            other => Err(Error::Config(format!(
                "dist_gemm algo must be ring|allgather|summa2d, got {other:?}"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DistGemmAlgo::AllGatherB => "allgather",
            DistGemmAlgo::RingPipelined => "ring",
            DistGemmAlgo::Summa2D => "summa2d",
        }
    }
}

/// Tunables for [`dist_gemm_with`] (the `[compute]` config section).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DistGemmOptions {
    pub algo: DistGemmAlgo,
    /// Split each owned B panel into sub-panels of at most this many rows
    /// before shifting (finer pipelining granularity); 0 = shift whole
    /// owned panels (the default, and the 2-panel memory contract). For
    /// Summa2D this is the k-panel width per broadcast step (0 =
    /// ceil(k/p)).
    pub panel_rows: usize,
    /// Process-grid shape for Summa2D (`"auto"` = most-square
    /// factorization of the grant size); ignored by the 1D algorithms.
    pub grid: GridSpec,
}

/// Per-call observability from the ring path (test hook + metrics feed).
#[derive(Debug, Clone, Copy, Default)]
pub struct RingStats {
    /// High-water mark of B-panel doubles resident on this rank
    /// (compute panel + receiver prefetch + any not-yet-retired
    /// in-flight send).
    pub peak_b_doubles: usize,
    /// Time inside the local GEMM kernel.
    pub compute_s: f64,
    /// Time stalled on the pipeline (enqueueing sends + awaiting recvs).
    pub wait_s: f64,
    /// Panels shifted through this rank.
    pub shifts: usize,
}

/// Per-call observability from the SUMMA path (test hook + metrics
/// feed). The peaks are per-pipeline analytic bounds from the
/// [`collectives::BcastPipeline`] channel discipline: at most two
/// schedule-consecutive panels resident per dimension.
#[derive(Debug, Clone, Copy, Default)]
pub struct SummaStats {
    /// High-water mark of A-panel doubles resident on this rank.
    pub peak_a_doubles: usize,
    /// High-water mark of B-panel doubles resident on this rank.
    pub peak_b_doubles: usize,
    /// Time stalled on A-panel broadcasts (row sub-mesh).
    pub row_bcast_s: f64,
    /// Time stalled on B-panel broadcasts (column sub-mesh).
    pub col_bcast_s: f64,
    /// Time inside the local GEMM kernel.
    pub compute_s: f64,
    /// Entry/exit redistribution plus pipeline teardown time.
    pub wait_s: f64,
    /// Broadcast steps executed (= ceil(k / panel width)).
    pub steps: usize,
    /// The resolved (p_r, p_c) grid.
    pub grid: (u32, u32),
}

/// All-gather a row-distributed matrix so every rank holds the full thing.
/// Requires RowBlock layout (panels concatenate contiguously). Gathers
/// straight into one flat pre-sized buffer (`collectives::allgather_flat`)
/// — no per-rank `Vec` staging, no re-concatenation copy.
pub fn allgather_matrix(mesh: &mut Mesh, panel: &LocalPanel) -> Result<DenseMatrix> {
    if panel.meta.layout.kind != LayoutKind::RowBlock {
        return Err(Error::Shape(
            "allgather_matrix requires RowBlock layout (redistribute first)".into(),
        ));
    }
    let layout = panel.layout();
    let cols = panel.meta.cols as usize;
    let counts: Vec<usize> =
        (0..layout.slots).map(|s| layout.local_count(s) as usize * cols).collect();
    let flat = collectives::allgather_flat(mesh, panel.local().data(), &counts)?;
    DenseMatrix::from_vec(panel.meta.rows as usize, cols, flat)
}

/// SPMD distributed GEMM with the default options (ring-pipelined, whole
/// owned panels): every session worker passes its panels of A and B;
/// returns its panel of C = A·B with C row-distributed like A.
pub fn dist_gemm(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    c_handle: u64,
    backend: &dyn GemmBackend,
) -> Result<LocalPanel> {
    dist_gemm_with(mesh, a, b, c_handle, backend, &DistGemmOptions::default())
}

/// SPMD distributed GEMM with explicit algorithm/panel options.
pub fn dist_gemm_with(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    c_handle: u64,
    backend: &dyn GemmBackend,
    opts: &DistGemmOptions,
) -> Result<LocalPanel> {
    dist_gemm_with_cancel(mesh, a, b, c_handle, backend, opts, None)
}

/// [`dist_gemm_with`] plus a cooperative cancel token, checked at
/// panel-step boundaries. Cancellation preserves the collective protocol:
/// a flagged rank keeps shifting/forwarding panels (skipping only the
/// local compute) and all ranks agree on the flag in one scalar
/// all-reduce after the panel sweep, so either every rank returns
/// [`Error::Cancelled`] or none does — the mesh is never left desynced.
pub fn dist_gemm_with_cancel(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    c_handle: u64,
    backend: &dyn GemmBackend,
    opts: &DistGemmOptions,
    cancel: Option<&CancelToken>,
) -> Result<LocalPanel> {
    validate_operands(mesh, a, b)?;
    let rank = mesh.rank();
    let m = compute_metrics();
    m.backend.set(backend_code(backend.name()));
    let (c_panel, grid) = match opts.algo {
        DistGemmAlgo::AllGatherB => {
            m.allgather_gemms.inc(1);
            let c_local =
                dist_gemm_allgather_local(mesh, a, b, backend, opts.panel_rows, cancel)?;
            (wrap_output(a, b, c_handle, c_local)?, (mesh.size() as u32, 1))
        }
        DistGemmAlgo::RingPipelined => {
            m.ring_gemms.inc(1);
            let (c_local, stats) =
                dist_gemm_ring_local(mesh, a, b, backend, opts.panel_rows, cancel)?;
            m.phases.add(
                &format!("ring_compute_r{rank}"),
                std::time::Duration::from_secs_f64(stats.compute_s),
            );
            m.phases.add(
                &format!("ring_wait_r{rank}"),
                std::time::Duration::from_secs_f64(stats.wait_s),
            );
            m.peak_b_doubles.set_max(stats.peak_b_doubles as i64);
            (wrap_output(a, b, c_handle, c_local)?, (mesh.size() as u32, 1))
        }
        DistGemmAlgo::Summa2D => {
            m.summa_gemms.inc(1);
            let (c_panel, stats) = dist_gemm_summa_local(
                mesh,
                a,
                b,
                c_handle,
                backend,
                opts.panel_rows,
                opts.grid,
                cancel,
            )?;
            for (phase, secs) in [
                ("row_bcast", stats.row_bcast_s),
                ("col_bcast", stats.col_bcast_s),
                ("compute", stats.compute_s),
                ("wait", stats.wait_s),
            ] {
                m.phases.add(
                    &format!("summa_{phase}_r{rank}"),
                    std::time::Duration::from_secs_f64(secs),
                );
            }
            m.peak_a_doubles.set_max(stats.peak_a_doubles as i64);
            m.peak_b_doubles.set_max(stats.peak_b_doubles as i64);
            (c_panel, stats.grid)
        }
    };
    m.grid_r.set(grid.0 as i64);
    m.grid_c.set(grid.1 as i64);
    Ok(c_panel)
}

/// Ring-pipelined distributed GEMM returning the per-rank [`RingStats`] —
/// the prop suite asserts the two-panel memory contract through this.
pub fn dist_gemm_ring_with_stats(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    c_handle: u64,
    backend: &dyn GemmBackend,
    panel_rows: usize,
) -> Result<(LocalPanel, RingStats)> {
    validate_operands(mesh, a, b)?;
    let (c_local, stats) = dist_gemm_ring_local(mesh, a, b, backend, panel_rows, None)?;
    Ok((wrap_output(a, b, c_handle, c_local)?, stats))
}

/// 2D SUMMA distributed GEMM returning the per-rank [`SummaStats`] — the
/// prop suite asserts the per-dimension two-panel memory contract
/// through this.
pub fn dist_gemm_summa_with_stats(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    c_handle: u64,
    backend: &dyn GemmBackend,
    panel_rows: usize,
    grid: GridSpec,
) -> Result<(LocalPanel, SummaStats)> {
    validate_operands(mesh, a, b)?;
    dist_gemm_summa_local(mesh, a, b, c_handle, backend, panel_rows, grid, None)
}

fn validate_operands(mesh: &Mesh, a: &LocalPanel, b: &LocalPanel) -> Result<()> {
    if a.meta.cols != b.meta.rows {
        return Err(Error::Shape(format!(
            "dist_gemm: A is {}x{}, B is {}x{}",
            a.meta.rows, a.meta.cols, b.meta.rows, b.meta.cols
        )));
    }
    if a.meta.layout.kind != LayoutKind::RowBlock {
        return Err(Error::Shape("dist_gemm requires RowBlock A".into()));
    }
    if b.meta.layout.kind != LayoutKind::RowBlock {
        return Err(Error::Shape("dist_gemm requires RowBlock B".into()));
    }
    let p = mesh.size() as u32;
    if a.layout().slots != p || b.layout().slots != p {
        return Err(Error::Shape(format!(
            "dist_gemm: A has {} owners, B has {}, mesh has {p} ranks",
            a.layout().slots,
            b.layout().slots
        )));
    }
    let rank = mesh.rank() as u32;
    if a.slot != rank || b.slot != rank {
        return Err(Error::Shape(format!(
            "dist_gemm: rank {rank} holds A slot {} / B slot {} (slots must follow mesh ranks)",
            a.slot, b.slot
        )));
    }
    Ok(())
}

fn wrap_output(a: &LocalPanel, b: &LocalPanel, c_handle: u64, c_local: DenseMatrix) -> Result<LocalPanel> {
    let c_meta = MatrixMeta {
        handle: c_handle,
        rows: a.meta.rows,
        cols: b.meta.cols,
        layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: a.meta.layout.owners.clone() },
    };
    LocalPanel::from_local(c_meta, a.slot, c_local)
}

/// Contiguous global k-ranges `(k0, rows)` of `origin`'s owned B rows,
/// split into chunks of at most `panel_rows` rows (0 = one chunk).
fn sub_panels(layout: &Layout, origin: u32, panel_rows: usize) -> Vec<(u64, usize)> {
    let count = layout.local_count(origin) as usize;
    if count == 0 {
        return Vec::new();
    }
    let start = layout.global_index(origin, 0);
    let w = if panel_rows == 0 { count } else { panel_rows };
    let mut out = Vec::with_capacity((count + w - 1) / w);
    let mut off = 0usize;
    while off < count {
        let rows = w.min(count - off);
        out.push((start + off as u64, rows));
        off += rows;
    }
    out
}

/// `C_local += A_local[:, k0..k0+panel.rows()] · panel`. The one place
/// both algorithms call the backend — identical calls in identical order
/// is what makes ring and allgather outputs bit-identical.
///
/// The A column slice is materialized with `block_padded` (one extra
/// copy of A_local per dist_gemm call, amortized over the panels). This
/// is deliberate: the pluggable backend takes whole `DenseMatrix`
/// operands (the PJRT path uploads them as-is), and the copy is
/// O(m·k) against the call's O(m·k·n) FLOPs — noise for any n beyond a
/// few columns. Fusing the slice into `pack_a` would save it for the
/// native backend only, at the cost of a second backend entry point.
fn accumulate_panel(
    backend: &dyn GemmBackend,
    a_local: &DenseMatrix,
    k0: usize,
    panel: &DenseMatrix,
    c: &mut DenseMatrix,
) -> Result<()> {
    if panel.rows() == 0 {
        return Ok(());
    }
    let a_cols = a_local.block_padded(0, k0, a_local.rows(), panel.rows());
    backend.gemm_acc(&a_cols, panel, c)
}

/// Legacy baseline: materialize full B, then run the identical
/// ascending-k panel schedule the other algorithms use.
fn dist_gemm_allgather_local(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    backend: &dyn GemmBackend,
    panel_rows: usize,
    cancel: Option<&CancelToken>,
) -> Result<DenseMatrix> {
    let b_full = allgather_matrix(mesh, b)?;
    let p = mesh.size() as u32;
    let layout_b = b.layout();
    let n = b.meta.cols as usize;
    let mut c = DenseMatrix::zeros(a.local_rows(), n);
    for origin in 0..p {
        for (k0, rows) in sub_panels(&layout_b, origin, panel_rows) {
            // Cancelled ranks skip the compute only; the flag is agreed
            // collectively below before anyone returns.
            if cancel.is_some_and(|t| t.is_cancelled()) {
                continue;
            }
            let panel = b_full.block_padded(k0 as usize, 0, rows, n);
            accumulate_panel(backend, a.local(), k0 as usize, &panel, &mut c)?;
        }
    }
    agree_not_cancelled(mesh, cancel, "gemm (allgather)")?;
    Ok(c)
}

/// Collective cancel agreement after a panel sweep: every rank returns
/// `Err(Cancelled)` iff any rank's token was set. No-op without a token
/// (plain `dist_gemm_with` calls stay bitwise-identical to before).
fn agree_not_cancelled(
    mesh: &mut Mesh,
    cancel: Option<&CancelToken>,
    what: &str,
) -> Result<()> {
    let Some(token) = cancel else { return Ok(()) };
    let flagged = if mesh.size() == 1 {
        token.is_cancelled()
    } else {
        collectives::allreduce_flag(mesh, token.is_cancelled())?
    };
    if flagged {
        return Err(Error::Cancelled(format!("{what} cancelled mid-panel-sweep")));
    }
    Ok(())
}

/// Peak doubles resident for one pipeline's frame-size sequence. With a
/// [`collectives::BcastPipeline`] in play at most two
/// schedule-consecutive frames coexist (compute panel + either the
/// previous frame draining onto the wire or the receiver's one-frame
/// read-ahead — see the pipeline's channel-discipline docs); without one
/// (singleton dimension) panels are materialized one at a time.
fn peak_frames(sizes: impl Iterator<Item = usize>, piped: bool) -> usize {
    let sizes: Vec<usize> = sizes.collect();
    match sizes.len() {
        0 => 0,
        1 => sizes[0],
        _ if !piped => sizes.iter().copied().max().unwrap_or(0),
        _ => sizes.windows(2).map(|pair| pair[0] + pair[1]).max().unwrap_or(0),
    }
}

/// The 1D chain (p×1 SUMMA): every rank walks origins 0..p in ascending
/// order — so k0 ascends globally — sourcing its own panels into a
/// [`collectives::BcastPipeline`] over the whole mesh and receiving
/// everyone else's in schedule order. Store-and-forward gating inside
/// the pipeline bounds residency at two schedule-consecutive panels.
fn dist_gemm_ring_local(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    backend: &dyn GemmBackend,
    panel_rows: usize,
    cancel: Option<&CancelToken>,
) -> Result<(DenseMatrix, RingStats)> {
    let p = mesh.size();
    let rank = mesh.rank();
    let layout_b = b.layout();
    let n = b.meta.cols as usize;
    let mut c = DenseMatrix::zeros(a.local_rows(), n);
    let mut stats = RingStats::default();

    // Schedule: (origin, k0, rows) in compute order — ascending origin,
    // hence globally ascending k0, identical on every rank.
    let schedule: Vec<(u32, u64, usize)> = (0..p as u32)
        .flat_map(|origin| {
            sub_panels(&layout_b, origin, panel_rows)
                .into_iter()
                .map(move |(k0, rows)| (origin, k0, rows))
        })
        .collect();

    if p == 1 {
        let t = Timer::start();
        for &(_, k0, rows) in &schedule {
            if cancel.is_some_and(|tok| tok.is_cancelled()) {
                return Err(Error::Cancelled("gemm cancelled mid-panel-sweep".into()));
            }
            let li0 = layout_b.local_index(k0) as usize;
            let panel = DenseMatrix::from_vec(
                rows,
                n,
                b.local().data()[li0 * n..(li0 + rows) * n].to_vec(),
            )?;
            stats.peak_b_doubles = stats.peak_b_doubles.max(rows * n);
            accumulate_panel(backend, a.local(), k0 as usize, &panel, &mut c)?;
        }
        stats.compute_s = t.elapsed_secs();
        return Ok((c, stats));
    }

    stats.peak_b_doubles = peak_frames(schedule.iter().map(|&(_, _, r)| r * n), true);

    let sub = SubMesh::new(mesh, (0..p).collect())?;
    let bcast_sched: Vec<(usize, collectives::FrameShape)> = schedule
        .iter()
        .map(|&(origin, _, rows)| (origin as usize, collectives::FrameShape::Matrix(rows, n)))
        .collect();
    let pipe = collectives::bcast_pipelined(mesh, &sub, &bcast_sched)?;

    for &(origin, k0, rows) in &schedule {
        let panel: Arc<DenseMatrix> = if origin as usize == rank {
            let li0 = layout_b.local_index(k0) as usize;
            let t = Timer::start();
            let arc = pipe.send_own(|| {
                Ok(Arc::new(DenseMatrix::from_vec(
                    rows,
                    n,
                    b.local().data()[li0 * n..(li0 + rows) * n].to_vec(),
                )?))
            })?;
            stats.wait_s += t.elapsed_secs();
            arc
        } else {
            let t = Timer::start();
            let got = pipe.recv()?; // shape-checked by the receiver
            stats.wait_s += t.elapsed_secs();
            got
        };
        stats.shifts += 1;

        // A cancelled rank must keep the chain protocol alive (send/recv
        // above still ran) — it only skips the local kernel. All ranks
        // agree on the flag after the sweep, below.
        if cancel.is_some_and(|tok| tok.is_cancelled()) {
            continue;
        }
        let t = Timer::start();
        accumulate_panel(backend, a.local(), k0 as usize, &panel, &mut c)?;
        stats.compute_s += t.elapsed_secs();
    }
    let t = Timer::start();
    pipe.finish()?;
    stats.wait_s += t.elapsed_secs();
    agree_not_cancelled(mesh, cancel, "gemm (ring)")?;
    Ok((c, stats))
}

/// True 2D SUMMA over a p_r × p_c grid.
///
/// Entry: A and B are redistributed from RowBlock into block-cyclic 2D
/// layouts whose cyclic block width along k equals the panel width `w`,
/// so the owner of step t's panel holds it as one contiguous local
/// block. Step t broadcasts A's k-columns [t·w, t·w+w) from grid column
/// `t % p_c` along each grid row, and B's k-rows from grid row
/// `t % p_r` along each grid column — two concurrent
/// [`collectives::BcastPipeline`]s per rank, each delivering frames in
/// ascending-t order — then every rank folds
/// `C_local += A_panel · B_panel`. Ascending t means globally ascending
/// k: bit-identical to the 1D algorithms and to a local GEMM. Exit: C
/// (pure-block × pure-block) is redistributed back to RowBlock.
///
/// Cancellation is cooperative per step: a flagged rank keeps both
/// broadcast pipelines fed (frames still flow) and skips only the local
/// kernel; the flag is agreed in one scalar all-reduce after the sweep,
/// so every rank returns [`Error::Cancelled`] together or none does.
#[allow(clippy::too_many_arguments)]
fn dist_gemm_summa_local(
    mesh: &mut Mesh,
    a: &LocalPanel,
    b: &LocalPanel,
    c_handle: u64,
    backend: &dyn GemmBackend,
    panel_rows: usize,
    grid_spec: GridSpec,
    cancel: Option<&CancelToken>,
) -> Result<(LocalPanel, SummaStats)> {
    let p = mesh.size();
    let rank = mesh.rank() as u32;
    let grid = grid_spec.resolve(p as u32)?;
    let (p_r, p_c) = (grid.p_r, grid.p_c);
    let (m_rows, k, n) = (a.meta.rows, a.meta.cols, b.meta.cols);
    let w = if panel_rows == 0 { k.div_ceil(p as u64).max(1) } else { panel_rows as u64 };
    let steps = k.div_ceil(w) as usize;
    let wt = |t: usize| w.min(k - t as u64 * w) as usize;

    // k-cyclic block width == panel width: the owner's panel for step t
    // is a contiguous local block at offset (t / q)·w.
    let dist_a = BlockCyclic2D::new(grid, m_rows, k, m_rows.div_ceil(p_r as u64).max(1), w)?;
    let dist_b = BlockCyclic2D::new(grid, k, n, w, n.div_ceil(p_c as u64).max(1))?;
    let (my_r, my_c) = (grid.row_of(rank), grid.col_of(rank));

    let mut stats = SummaStats { steps, grid: (p_r, p_c), ..SummaStats::default() };

    let t0 = Timer::start();
    let a2 = rowblock_to_grid(mesh, a, &dist_a)?;
    let b2 = rowblock_to_grid(mesh, b, &dist_b)?;
    stats.wait_s += t0.elapsed_secs();
    let a_rows = a2.rows();
    let b_cols = b2.cols();
    let mut c = DenseMatrix::zeros(a_rows, b_cols);

    // One pipeline per non-singleton grid dimension. The two use
    // disjoint neighbor links (row neighbors are rank±1, column
    // neighbors rank±p_c), so their sender/receiver thread pairs never
    // share a socket.
    let row_pipe = if p_c >= 2 && steps > 0 {
        let sub =
            SubMesh::new(mesh, (0..p_c).map(|gc| grid.rank_of(my_r, gc) as usize).collect())?;
        let sched: Vec<(usize, collectives::FrameShape)> = (0..steps)
            .map(|t| (t % p_c as usize, collectives::FrameShape::Matrix(a_rows, wt(t))))
            .collect();
        Some(collectives::bcast_pipelined(mesh, &sub, &sched)?)
    } else {
        None
    };
    let col_pipe = if p_r >= 2 && steps > 0 {
        let sub =
            SubMesh::new(mesh, (0..p_r).map(|gr| grid.rank_of(gr, my_c) as usize).collect())?;
        let sched: Vec<(usize, collectives::FrameShape)> = (0..steps)
            .map(|t| (t % p_r as usize, collectives::FrameShape::Matrix(wt(t), b_cols)))
            .collect();
        Some(collectives::bcast_pipelined(mesh, &sub, &sched)?)
    } else {
        None
    };

    stats.peak_a_doubles = peak_frames((0..steps).map(|t| a_rows * wt(t)), row_pipe.is_some());
    stats.peak_b_doubles = peak_frames((0..steps).map(|t| wt(t) * b_cols), col_pipe.is_some());

    for t in 0..steps {
        let wt_t = wt(t);
        let a_panel: Arc<DenseMatrix> = if t % p_c as usize == my_c as usize {
            let lj0 = (t / p_c as usize) * w as usize;
            let make = || Ok(Arc::new(a2.block_padded(0, lj0, a_rows, wt_t)));
            match &row_pipe {
                Some(pipe) => {
                    let tm = Timer::start();
                    let got = pipe.send_own(make)?;
                    stats.row_bcast_s += tm.elapsed_secs();
                    got
                }
                None => make()?,
            }
        } else {
            let tm = Timer::start();
            let got = row_pipe.as_ref().expect("a non-owner rank implies p_c >= 2").recv()?;
            stats.row_bcast_s += tm.elapsed_secs();
            got
        };
        let b_panel: Arc<DenseMatrix> = if t % p_r as usize == my_r as usize {
            let li0 = (t / p_r as usize) * w as usize;
            let make = || Ok(Arc::new(b2.block_padded(li0, 0, wt_t, b_cols)));
            match &col_pipe {
                Some(pipe) => {
                    let tm = Timer::start();
                    let got = pipe.send_own(make)?;
                    stats.col_bcast_s += tm.elapsed_secs();
                    got
                }
                None => make()?,
            }
        } else {
            let tm = Timer::start();
            let got = col_pipe.as_ref().expect("a non-owner rank implies p_r >= 2").recv()?;
            stats.col_bcast_s += tm.elapsed_secs();
            got
        };

        // A cancelled rank must keep both broadcasts alive (the frame
        // exchanges above still ran) — it only skips the local kernel.
        if cancel.is_some_and(|tok| tok.is_cancelled()) {
            continue;
        }
        let tm = Timer::start();
        backend.gemm_acc(&a_panel, &b_panel, &mut c)?;
        stats.compute_s += tm.elapsed_secs();
    }

    let tm = Timer::start();
    if let Some(pipe) = row_pipe {
        pipe.finish()?;
    }
    if let Some(pipe) = col_pipe {
        pipe.finish()?;
    }
    // C is (pure-block rows) × (pure-block cols): convert back to the
    // RowBlock panels the 1D world (and wrap_output's contract) expects.
    let dist_c = BlockCyclic2D::new(
        grid,
        m_rows,
        n,
        m_rows.div_ceil(p_r as u64).max(1),
        n.div_ceil(p_c as u64).max(1),
    )?;
    let c_meta = MatrixMeta {
        handle: c_handle,
        rows: m_rows,
        cols: n,
        layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: a.meta.layout.owners.clone() },
    };
    let c_panel = grid_to_rowblock(mesh, &c, &dist_c, c_meta)?;
    stats.wait_s += tm.elapsed_secs();
    agree_not_cancelled(mesh, cancel, "gemm (summa)")?;
    Ok((c_panel, stats))
}

/// Analytic per-rank broadcast volume of one Summa2D sweep: the doubles
/// rank (0,0) *receives* — every A panel rooted in another grid column
/// plus every B panel rooted in another grid row. Exact (no measurement
/// needed), so the bench's bytes-moved ablation works without running
/// the mesh; multiply by 8 for bytes. Square grids receive O(1/√p) of
/// what the 1D shapes (1×p / p×1) move.
pub fn summa_bcast_doubles_per_rank(
    grid: Grid,
    m: u64,
    k: u64,
    n: u64,
    panel_rows: usize,
) -> u64 {
    let p = grid.size() as u64;
    let w = if panel_rows == 0 { k.div_ceil(p).max(1) } else { panel_rows as u64 };
    let dist_a = BlockCyclic2D { grid, rows: m, cols: k, row_block: m.div_ceil(grid.p_r as u64).max(1), col_block: w };
    let dist_b = BlockCyclic2D { grid, rows: k, cols: n, row_block: w, col_block: n.div_ceil(grid.p_c as u64).max(1) };
    let (a_rows0, b_cols0) = (dist_a.local_rows(0), dist_b.local_cols(0));
    let mut total = 0u64;
    for t in 0..k.div_ceil(w) {
        let wt = w.min(k - t * w);
        if t % grid.p_c as u64 != 0 {
            total += a_rows0 * wt;
        }
        if t % grid.p_r as u64 != 0 {
            total += wt * b_cols0;
        }
    }
    total
}

/// Distributed Frobenius norm: local partial + scalar all-reduce.
pub fn dist_frobenius(mesh: &mut Mesh, panel: &LocalPanel) -> Result<f64> {
    let local: f64 = panel.local().data().iter().map(|x| x * x).sum();
    let mut buf = vec![local];
    collectives::allreduce_sum(mesh, &mut buf, collectives::AllReduceAlgo::Ring)?;
    Ok(buf[0].sqrt())
}

/// Distributed Gram matvec: w = Aᵀ(A v) with A row-distributed; v and w
/// are replicated length-n vectors. One ring all-reduce per application —
/// the Lanczos hot path. The local two-sided product is delegated to the
/// backend-agnostic closure `local_gram` so callers can route it through
/// PJRT (fused gram artifact) or native kernels.
pub fn dist_gram_matvec(
    mesh: &mut Mesh,
    v: &[f64],
    local_gram: impl FnOnce(&[f64]) -> Result<Vec<f64>>,
) -> Result<Vec<f64>> {
    let mut w = local_gram(v)?;
    collectives::allreduce_sum(mesh, &mut w, collectives::AllReduceAlgo::Ring)?;
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_mesh;
    use crate::elemental::panel::{gather_matrix, scatter_matrix};
    use crate::linalg::gemm::gemm;
    use crate::workload::random_matrix;

    fn meta(handle: u64, rows: u64, cols: u64, p: u32) -> MatrixMeta {
        MatrixMeta {
            handle,
            rows,
            cols,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: (0..p).collect() },
        }
    }

    fn run_dist_gemm(
        m: u64,
        k: u64,
        n: u64,
        p: usize,
        opts: DistGemmOptions,
        seed: u64,
    ) -> (DenseMatrix, DenseMatrix) {
        let a_full =
            DenseMatrix::from_vec(m as usize, k as usize, random_matrix(seed, m as usize, k as usize))
                .unwrap();
        let b_full = DenseMatrix::from_vec(
            k as usize,
            n as usize,
            random_matrix(seed + 1, k as usize, n as usize),
        )
        .unwrap();
        let a_panels = Arc::new(scatter_matrix(&meta(1, m, k, p as u32), &a_full).unwrap());
        let b_panels = Arc::new(scatter_matrix(&meta(2, k, n, p as u32), &b_full).unwrap());
        let c_panels = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            dist_gemm_with(&mut mesh, &a_panels[rank], &b_panels[rank], 3, &NativeBackend, &opts)
        })
        .unwrap();
        let c = gather_matrix(&c_panels).unwrap();
        let want = gemm(&a_full, &b_full).unwrap();
        (c, want)
    }

    #[test]
    fn dist_gemm_matches_local() {
        let (c, want) = run_dist_gemm(37, 11, 8, 3, DistGemmOptions::default(), 1);
        assert!(c.max_abs_diff(&want).unwrap() < 1e-10);
    }

    const ALL_ALGOS: [DistGemmAlgo; 3] =
        [DistGemmAlgo::RingPipelined, DistGemmAlgo::AllGatherB, DistGemmAlgo::Summa2D];

    #[test]
    fn all_algorithms_match_local_across_shapes() {
        // ragged (p does not divide k), p > k, narrow sub-panels, prime p
        for (m, k, n, p, w) in [
            (20u64, 7u64, 5u64, 3usize, 0usize),
            (9, 2, 4, 4, 0), // p > k: whole grid rows/cols own no k-block
            (16, 12, 6, 4, 2),
            (8, 5, 3, 1, 2),  // solo mesh
            (11, 9, 7, 5, 0), // prime p: summa falls back to 5x1
        ] {
            for algo in ALL_ALGOS {
                let opts = DistGemmOptions { algo, panel_rows: w, grid: GridSpec::Auto };
                let (c, want) = run_dist_gemm(m, k, n, p, opts, 7);
                assert!(
                    c.max_abs_diff(&want).unwrap() < 1e-10,
                    "{algo:?} m={m} k={k} n={n} p={p} w={w}"
                );
            }
        }
    }

    #[test]
    fn all_algorithms_are_bitwise_equal_to_local() {
        // The determinism contract: ascending-k panel schedules + the
        // split-invariant native kernel make every algorithm, grid shape
        // and panel width produce the exact bits of a local gemm.
        for (m, k, n, p, w) in [(21u64, 13u64, 9u64, 4usize, 0usize), (10, 6, 4, 3, 2)] {
            for algo in ALL_ALGOS {
                let (c, want) = run_dist_gemm(
                    m, k, n, p,
                    DistGemmOptions { algo, panel_rows: w, grid: GridSpec::Auto },
                    9,
                );
                assert_eq!(c, want, "{algo:?} m={m} k={k} n={n} p={p} w={w}");
            }
        }
        // explicit grid shapes, including both 1D degenerations
        for spec in [GridSpec::Fixed(2, 2), GridSpec::Fixed(1, 4), GridSpec::Fixed(4, 1)] {
            let (c, want) = run_dist_gemm(
                21, 13, 9, 4,
                DistGemmOptions { algo: DistGemmAlgo::Summa2D, panel_rows: 3, grid: spec },
                9,
            );
            assert_eq!(c, want, "summa2d grid {}", spec.name());
        }
    }

    #[test]
    fn ring_memory_contract_and_stats() {
        let (m, k, n, p) = (24u64, 10u64, 6u64, 3usize);
        let a_full =
            DenseMatrix::from_vec(m as usize, k as usize, random_matrix(3, m as usize, k as usize))
                .unwrap();
        let b_full =
            DenseMatrix::from_vec(k as usize, n as usize, random_matrix(4, k as usize, n as usize))
                .unwrap();
        let a_panels = Arc::new(scatter_matrix(&meta(1, m, k, p as u32), &a_full).unwrap());
        let b_panels = Arc::new(scatter_matrix(&meta(2, k, n, p as u32), &b_full).unwrap());
        let results = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            dist_gemm_ring_with_stats(
                &mut mesh,
                &a_panels[rank],
                &b_panels[rank],
                3,
                &NativeBackend,
                0,
            )
        })
        .unwrap();
        let bound = 2 * ((k as usize + p - 1) / p) * n as usize;
        for (panel, stats) in &results {
            assert!(
                stats.peak_b_doubles <= bound,
                "peak {} > 2·ceil(k/p)·n = {bound}",
                stats.peak_b_doubles
            );
            assert_eq!(stats.shifts, p, "every origin's panel visits every rank once");
            assert_eq!(panel.meta.handle, 3);
        }
        let c = gather_matrix(&results.iter().map(|(c, _)| c.clone()).collect::<Vec<_>>()).unwrap();
        let want = gemm(&a_full, &b_full).unwrap();
        assert!(c.max_abs_diff(&want).unwrap() < 1e-10);
    }

    #[test]
    fn summa_memory_contract_and_stats() {
        let (m, k, n, p) = (24u64, 20u64, 12u64, 4usize);
        let w = 5usize; // steps = ceil(20/5) = 4
        let a_full =
            DenseMatrix::from_vec(m as usize, k as usize, random_matrix(3, m as usize, k as usize))
                .unwrap();
        let b_full =
            DenseMatrix::from_vec(k as usize, n as usize, random_matrix(4, k as usize, n as usize))
                .unwrap();
        let a_panels = Arc::new(scatter_matrix(&meta(1, m, k, p as u32), &a_full).unwrap());
        let b_panels = Arc::new(scatter_matrix(&meta(2, k, n, p as u32), &b_full).unwrap());
        let results = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            dist_gemm_summa_with_stats(
                &mut mesh,
                &a_panels[rank],
                &b_panels[rank],
                3,
                &NativeBackend,
                w,
                GridSpec::Fixed(2, 2),
            )
        })
        .unwrap();
        // Store-and-forward gating bounds temps at two in-flight panels
        // per dimension: 2·ceil(m/p_r)·w for A, 2·w·ceil(n/p_c) for B.
        let a_bound = 2 * (m as usize).div_ceil(2) * w;
        let b_bound = 2 * w * (n as usize).div_ceil(2);
        for (panel, stats) in &results {
            assert_eq!(stats.grid, (2, 2));
            assert_eq!(stats.steps, (k as usize).div_ceil(w));
            assert!(
                stats.peak_a_doubles <= a_bound,
                "peak A {} > 2·ceil(m/p_r)·w = {a_bound}",
                stats.peak_a_doubles
            );
            assert!(
                stats.peak_b_doubles <= b_bound,
                "peak B {} > 2·w·ceil(n/p_c) = {b_bound}",
                stats.peak_b_doubles
            );
            assert_eq!(panel.meta.handle, 3);
        }
        let c = gather_matrix(&results.iter().map(|(c, _)| c.clone()).collect::<Vec<_>>()).unwrap();
        let want = gemm(&a_full, &b_full).unwrap();
        assert_eq!(c, want, "summa2d must match the local kernel bitwise");
    }

    #[test]
    fn summa_byte_model_prefers_square_grids() {
        // The analytic per-rank broadcast volume that the bench grid sweep
        // reports: an auto (square) grid must beat both 1D degenerations.
        let square = summa_bcast_doubles_per_rank(Grid::new(2, 2).unwrap(), 512, 512, 512, 128);
        let wide = summa_bcast_doubles_per_rank(Grid::new(1, 4).unwrap(), 512, 512, 512, 128);
        let tall = summa_bcast_doubles_per_rank(Grid::new(4, 1).unwrap(), 512, 512, 512, 128);
        assert_eq!(square, 131072);
        assert_eq!(wide, 196608);
        assert_eq!(tall, 196608);
        assert!(square < wide && square < tall);
    }

    #[test]
    fn algo_parsing() {
        assert_eq!(DistGemmAlgo::parse("ring").unwrap(), DistGemmAlgo::RingPipelined);
        assert_eq!(DistGemmAlgo::parse("allgather").unwrap(), DistGemmAlgo::AllGatherB);
        assert_eq!(DistGemmAlgo::parse("summa2d").unwrap(), DistGemmAlgo::Summa2D);
        assert!(DistGemmAlgo::parse("summa3d").is_err());
        assert_eq!(DistGemmAlgo::default().name(), "ring");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a_full = DenseMatrix::zeros(4, 3);
        let b_full = DenseMatrix::zeros(5, 2);
        let ap = Arc::new(scatter_matrix(&meta(1, 4, 3, 1), &a_full).unwrap());
        let bp = Arc::new(scatter_matrix(&meta(2, 5, 2, 1), &b_full).unwrap());
        let res = run_mesh(1, move |mut mesh| {
            match dist_gemm(&mut mesh, &ap[0], &bp[0], 3, &NativeBackend) {
                Err(crate::Error::Shape(_)) => Ok(true),
                _ => Ok(false),
            }
        })
        .unwrap();
        assert!(res[0]);
    }

    #[test]
    fn empty_matrices_are_fine() {
        // k = 0 (no panels anywhere) and n = 0 (zero-width panels)
        for (m, k, n, p) in [(6u64, 0u64, 4u64, 2usize), (6, 5, 0, 2), (0, 3, 2, 2)] {
            for algo in ALL_ALGOS {
                let opts = DistGemmOptions { algo, panel_rows: 0, grid: GridSpec::Auto };
                let (c, want) = run_dist_gemm(m, k, n, p, opts, 11);
                assert_eq!(c, want, "{algo:?} m={m} k={k} n={n}");
            }
        }
    }

    #[test]
    fn dist_frobenius_matches_local() {
        let full = DenseMatrix::from_vec(10, 4, random_matrix(5, 10, 4)).unwrap();
        let panels = Arc::new(scatter_matrix(&meta(1, 10, 4, 2), &full).unwrap());
        let want = full.frobenius_norm();
        let got = run_mesh(2, move |mut mesh| {
            let rank = mesh.rank();
            dist_frobenius(&mut mesh, &panels[rank])
        })
        .unwrap();
        for g in got {
            assert!((g - want).abs() < 1e-10);
        }
    }

    #[test]
    fn dist_gram_matvec_matches_dense() {
        let (m, n, p) = (20usize, 6usize, 2usize);
        let full = DenseMatrix::from_vec(m, n, random_matrix(7, m, n)).unwrap();
        let panels = Arc::new(scatter_matrix(&meta(1, m as u64, n as u64, p as u32), &full).unwrap());
        let v: Vec<f64> = (0..n).map(|i| (i + 1) as f64).collect();
        let v2 = v.clone();
        let got = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            let panel = &panels[rank];
            dist_gram_matvec(&mut mesh, &v2, |x| {
                let t = panel.local().matvec(x)?;
                panel.local().matvec_t(&t)
            })
        })
        .unwrap();
        // dense reference: w = Aᵀ A v
        let t = full.matvec(&v).unwrap();
        let want = full.matvec_t(&t).unwrap();
        for g in got {
            for (a, b) in g.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }
}
