//! Distributed dense matrix substrate — the Elemental (`DistMatrix`)
//! substitute.
//!
//! The original Alchemist stores data received from Spark executors in
//! Elemental `DistMatrix` objects and hands those to MPI routines (§2.2).
//! Here a distributed matrix is a [`messages::MatrixMeta`] (global shape +
//! [`layout`]) plus one [`LocalPanel`] per owner worker holding the locally
//! owned rows. Routines operate SPMD over panels with [`crate::comm`]
//! collectives, mirroring Elemental's communicator-scoped kernels.

pub mod dist_gemm;
pub mod layout;
pub mod panel;
pub mod redistribute;
pub mod store;
pub mod transpose;

pub use layout::{BlockCyclic2D, Grid, GridSpec, Layout};
pub use panel::LocalPanel;
pub use store::MatrixStore;
