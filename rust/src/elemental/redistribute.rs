//! Layout redistribution: convert a distributed matrix from one row
//! layout to another by an all-to-all row exchange over the session mesh.
//!
//! This is the "copying data from distributed data sets in Spark to
//! distributed matrices in Elemental requires some changes in the layout
//! of the data" step the paper calls out in §2.2, generalized so routines
//! can also re-lay out intermediates (the redistribution proptest checks
//! it is a permutation: no row lost, duplicated, or corrupted).

use crate::comm::Mesh;
use crate::elemental::{Layout, LocalPanel};
use crate::protocol::{LayoutDesc, LayoutKind, MatrixMeta, Reader, Writer};
use crate::{Error, Result};

/// SPMD: every session worker calls this with its panel of the source
/// matrix; returns its panel of the same matrix under `new_kind`.
/// Slot/rank correspondence: panel slot i == mesh rank i (the server
/// assigns session ranks in owner order).
pub fn redistribute(
    mesh: &mut Mesh,
    panel: &LocalPanel,
    new_handle: u64,
    new_kind: LayoutKind,
) -> Result<LocalPanel> {
    let p = mesh.size();
    if panel.meta.layout.owners.len() != p {
        return Err(Error::Shape(format!(
            "redistribute: {} owners vs mesh size {p}",
            panel.meta.layout.owners.len()
        )));
    }
    let new_meta = MatrixMeta {
        handle: new_handle,
        rows: panel.meta.rows,
        cols: panel.meta.cols,
        layout: LayoutDesc { kind: new_kind, owners: panel.meta.layout.owners.clone() },
    };
    let new_layout = Layout::from_desc(&new_meta.layout, new_meta.rows)?;
    let mut out = LocalPanel::alloc(new_meta, panel.slot)?;

    // Bucket our rows by destination slot.
    let mut buckets: Vec<Writer> = (0..p).map(|_| Writer::new()).collect();
    let mut counts = vec![0u32; p];
    for (r, row) in panel.iter_rows() {
        let dest = new_layout.owner_slot(r) as usize;
        buckets[dest].put_u64(r);
        buckets[dest].put_f64_slice(row);
        counts[dest] += 1;
    }

    // Keep our own rows.
    let mine = std::mem::take(&mut buckets[panel.slot as usize]).into_bytes();
    place_rows(&mut out, &mine, counts[panel.slot as usize])?;

    // Shifted all-to-all: at step s we send to rank+s and receive from
    // rank-s; Mesh::exchange overlaps the two so cycles cannot deadlock.
    let rank = mesh.rank();
    for s in 1..p {
        let to = (rank + s) % p;
        let from = (rank + p - s) % p;
        let mut payload = Writer::new();
        payload.put_u32(counts[to]);
        let body = std::mem::take(&mut buckets[to]).into_bytes();
        payload.reserve(body.len());
        let mut full = payload.into_bytes();
        full.extend_from_slice(&body);
        let got = mesh.exchange(to, &full, from)?;
        let mut r = Reader::new(&got);
        let n = r.get_u32()?;
        place_rows_reader(&mut out, &mut r, n)?;
    }
    Ok(out)
}

fn place_rows(out: &mut LocalPanel, bytes: &[u8], n: u32) -> Result<()> {
    let mut r = Reader::new(bytes);
    place_rows_reader(out, &mut r, n)
}

fn place_rows_reader(out: &mut LocalPanel, r: &mut Reader<'_>, n: u32) -> Result<()> {
    for _ in 0..n {
        let gr = r.get_u64()?;
        let vals = r.get_f64_slice()?;
        out.set_row(gr, &vals)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_mesh;
    use crate::elemental::panel::{gather_matrix, scatter_matrix};
    use crate::linalg::DenseMatrix;
    use crate::workload::random_matrix;
    use std::sync::Arc;

    fn run_redistribution(rows: u64, cols: u64, p: usize, from: LayoutKind, to: LayoutKind) {
        let meta = MatrixMeta {
            handle: 1,
            rows,
            cols,
            layout: LayoutDesc { kind: from, owners: (0..p as u32).collect() },
        };
        let full =
            DenseMatrix::from_vec(rows as usize, cols as usize, random_matrix(3, rows as usize, cols as usize))
                .unwrap();
        let panels = Arc::new(scatter_matrix(&meta, &full).unwrap());
        let panels2 = panels.clone();
        let out = run_mesh(p, move |mut mesh| {
            let mine = panels2[mesh.rank()].clone();
            redistribute(&mut mesh, &mine, 2, to)
        })
        .unwrap();
        let back = gather_matrix(&out).unwrap();
        assert_eq!(back, full, "{from:?} -> {to:?} p={p}");
        assert_eq!(out[0].meta.layout.kind, to);
        assert_eq!(out[0].meta.handle, 2);
    }

    #[test]
    fn block_to_cyclic_and_back() {
        run_redistribution(23, 3, 3, LayoutKind::RowBlock, LayoutKind::RowCyclic);
        run_redistribution(23, 3, 3, LayoutKind::RowCyclic, LayoutKind::RowBlock);
    }

    #[test]
    fn identity_redistribution() {
        run_redistribution(16, 2, 4, LayoutKind::RowBlock, LayoutKind::RowBlock);
    }

    #[test]
    fn single_worker() {
        run_redistribution(9, 2, 1, LayoutKind::RowBlock, LayoutKind::RowCyclic);
    }

    #[test]
    fn uneven_rows() {
        run_redistribution(17, 5, 4, LayoutKind::RowBlock, LayoutKind::RowCyclic);
    }
}
