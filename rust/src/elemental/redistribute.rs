//! Layout redistribution: convert a distributed matrix from one row
//! layout to another by an all-to-all row exchange over the session mesh.
//!
//! This is the "copying data from distributed data sets in Spark to
//! distributed matrices in Elemental requires some changes in the layout
//! of the data" step the paper calls out in §2.2, generalized so routines
//! can also re-lay out intermediates (the redistribution proptest checks
//! it is a permutation: no row lost, duplicated, or corrupted).

use crate::comm::Mesh;
use crate::elemental::{BlockCyclic2D, Layout, LocalPanel};
use crate::linalg::DenseMatrix;
use crate::protocol::{LayoutDesc, LayoutKind, MatrixMeta, Reader, Writer};
use crate::{Error, Result};

/// SPMD: every session worker calls this with its panel of the source
/// matrix; returns its panel of the same matrix under `new_kind`.
/// Slot/rank correspondence: panel slot i == mesh rank i (the server
/// assigns session ranks in owner order).
pub fn redistribute(
    mesh: &mut Mesh,
    panel: &LocalPanel,
    new_handle: u64,
    new_kind: LayoutKind,
) -> Result<LocalPanel> {
    let p = mesh.size();
    if panel.meta.layout.owners.len() != p {
        return Err(Error::Shape(format!(
            "redistribute: {} owners vs mesh size {p}",
            panel.meta.layout.owners.len()
        )));
    }
    let new_meta = MatrixMeta {
        handle: new_handle,
        rows: panel.meta.rows,
        cols: panel.meta.cols,
        layout: LayoutDesc { kind: new_kind, owners: panel.meta.layout.owners.clone() },
    };
    let new_layout = Layout::from_desc(&new_meta.layout, new_meta.rows)?;
    let mut out = LocalPanel::alloc(new_meta, panel.slot)?;

    // Bucket our rows by destination slot.
    let mut buckets: Vec<Writer> = (0..p).map(|_| Writer::new()).collect();
    let mut counts = vec![0u32; p];
    for (r, row) in panel.iter_rows() {
        let dest = new_layout.owner_slot(r) as usize;
        buckets[dest].put_u64(r);
        buckets[dest].put_f64_slice(row);
        counts[dest] += 1;
    }

    // Keep our own rows.
    let mine = std::mem::take(&mut buckets[panel.slot as usize]).into_bytes();
    place_rows(&mut out, &mine, counts[panel.slot as usize])?;

    // Shifted all-to-all: at step s we send to rank+s and receive from
    // rank-s; Mesh::exchange overlaps the two so cycles cannot deadlock.
    let rank = mesh.rank();
    for s in 1..p {
        let to = (rank + s) % p;
        let from = (rank + p - s) % p;
        let mut payload = Writer::new();
        payload.put_u32(counts[to]);
        let body = std::mem::take(&mut buckets[to]).into_bytes();
        payload.reserve(body.len());
        let mut full = payload.into_bytes();
        full.extend_from_slice(&body);
        let got = mesh.exchange(to, &full, from)?;
        let mut r = Reader::new(&got);
        let n = r.get_u32()?;
        place_rows_reader(&mut out, &mut r, n)?;
    }
    Ok(out)
}

fn place_rows(out: &mut LocalPanel, bytes: &[u8], n: u32) -> Result<()> {
    let mut r = Reader::new(bytes);
    place_rows_reader(out, &mut r, n)
}

fn place_rows_reader(out: &mut LocalPanel, r: &mut Reader<'_>, n: u32) -> Result<()> {
    for _ in 0..n {
        let gr = r.get_u64()?;
        let vals = r.get_f64_slice()?;
        out.set_row(gr, &vals)?;
    }
    Ok(())
}

fn check_grid_dist(mesh: &Mesh, dist: &BlockCyclic2D, rows: u64, cols: u64) -> Result<()> {
    if dist.grid.size() as usize != mesh.size() {
        return Err(Error::Shape(format!(
            "grid {}x{} needs {} ranks, mesh has {}",
            dist.grid.p_r,
            dist.grid.p_c,
            dist.grid.size(),
            mesh.size()
        )));
    }
    if dist.rows != rows || dist.cols != cols {
        return Err(Error::Shape(format!(
            "2D distribution is {}x{}, matrix is {rows}x{cols}",
            dist.rows, dist.cols
        )));
    }
    Ok(())
}

/// Scatter this rank's RowBlock panel into a 2D block-cyclic
/// distribution: returns this rank's dense local block (its owned rows ×
/// owned columns, both in local order). SPMD — one shifted all-to-all of
/// (row, column-block) segments over the session mesh, the same exchange
/// pattern as [`redistribute`] but bucketing contiguous column blocks
/// instead of whole rows. This is the entry conversion that lets
/// RowBlock uploads feed grid-distributed routines without any client
/// change.
pub fn rowblock_to_grid(
    mesh: &mut Mesh,
    panel: &LocalPanel,
    dist: &BlockCyclic2D,
) -> Result<DenseMatrix> {
    if panel.meta.layout.kind != LayoutKind::RowBlock {
        return Err(Error::Shape("rowblock_to_grid requires a RowBlock source".into()));
    }
    check_grid_dist(mesh, dist, panel.meta.rows, panel.meta.cols)?;
    let p = mesh.size();
    let rank = mesh.rank();
    let (my_r, my_c) = (dist.grid.row_of(rank as u32), dist.grid.col_of(rank as u32));
    let mut out =
        DenseMatrix::zeros(dist.local_rows(my_r) as usize, dist.local_cols(my_c) as usize);

    // Column blocks owned by each grid column (contiguous in both global
    // and local index space — the copy unit).
    let col_blocks: Vec<Vec<(u64, u64)>> =
        (0..dist.grid.p_c).map(|c| dist.col_blocks_of(c).collect()).collect();

    let mut buckets: Vec<Writer> = (0..p).map(|_| Writer::new()).collect();
    let mut counts = vec![0u32; p];
    for (r, row) in panel.iter_rows() {
        let dest_row = dist.owner_row(r);
        let lr = dist.local_row(r) as usize;
        for c in 0..dist.grid.p_c {
            let dest = dist.grid.rank_of(dest_row, c) as usize;
            for &(j0, w) in &col_blocks[c as usize] {
                let seg = &row[j0 as usize..(j0 + w) as usize];
                if dest == rank {
                    let lj = dist.local_col(j0) as usize;
                    out.row_mut(lr)[lj..lj + w as usize].copy_from_slice(seg);
                } else {
                    buckets[dest].put_u64(r);
                    buckets[dest].put_u64(j0);
                    buckets[dest].put_f64_slice(seg);
                    counts[dest] += 1;
                }
            }
        }
    }

    exchange_segments(mesh, buckets, &counts, |gr, j0, vals| {
        let lr = dist.local_row(gr) as usize;
        let lj = dist.local_col(j0) as usize;
        out.row_mut(lr)[lj..lj + vals.len()].copy_from_slice(vals);
        Ok(())
    })?;
    Ok(out)
}

/// Inverse of [`rowblock_to_grid`]: gather a 2D-distributed matrix back
/// into RowBlock panels (one per mesh rank, slot = rank). `meta` names
/// the resulting matrix (its layout must be RowBlock over the mesh) —
/// this is the exit conversion that hands grid-distributed results back
/// to the 1D world the client sees.
pub fn grid_to_rowblock(
    mesh: &mut Mesh,
    local: &DenseMatrix,
    dist: &BlockCyclic2D,
    meta: MatrixMeta,
) -> Result<LocalPanel> {
    if meta.layout.kind != LayoutKind::RowBlock {
        return Err(Error::Shape("grid_to_rowblock requires a RowBlock target".into()));
    }
    check_grid_dist(mesh, dist, meta.rows, meta.cols)?;
    let p = mesh.size();
    if meta.layout.owners.len() != p {
        return Err(Error::Shape(format!(
            "grid_to_rowblock: {} owners vs mesh size {p}",
            meta.layout.owners.len()
        )));
    }
    let rank = mesh.rank();
    let (my_r, my_c) = (dist.grid.row_of(rank as u32), dist.grid.col_of(rank as u32));
    if local.shape() != (dist.local_rows(my_r) as usize, dist.local_cols(my_c) as usize) {
        return Err(Error::Shape(format!(
            "grid_to_rowblock: local block is {}x{}, distribution says {}x{}",
            local.rows(),
            local.cols(),
            dist.local_rows(my_r),
            dist.local_cols(my_c)
        )));
    }
    let target = Layout::new(LayoutKind::RowBlock, dist.rows, p as u32)?;
    let mut out = DenseMatrix::zeros(target.local_count(rank as u32) as usize, dist.cols as usize);

    let my_col_blocks: Vec<(u64, u64)> = dist.col_blocks_of(my_c).collect();
    let mut buckets: Vec<Writer> = (0..p).map(|_| Writer::new()).collect();
    let mut counts = vec![0u32; p];
    for li in 0..local.rows() {
        let gr = dist.global_row(my_r, li as u64);
        let dest = target.owner_slot(gr) as usize;
        let mut lj = 0usize;
        for &(j0, w) in &my_col_blocks {
            let seg = &local.row(li)[lj..lj + w as usize];
            if dest == rank {
                let out_r = target.local_index(gr) as usize;
                out.row_mut(out_r)[j0 as usize..(j0 + w) as usize].copy_from_slice(seg);
            } else {
                buckets[dest].put_u64(gr);
                buckets[dest].put_u64(j0);
                buckets[dest].put_f64_slice(seg);
                counts[dest] += 1;
            }
            lj += w as usize;
        }
    }

    exchange_segments(mesh, buckets, &counts, |gr, j0, vals| {
        let out_r = target.local_index(gr) as usize;
        out.row_mut(out_r)[j0 as usize..j0 as usize + vals.len()].copy_from_slice(vals);
        Ok(())
    })?;
    LocalPanel::from_local(meta, rank as u32, out)
}

/// The shifted all-to-all under both 2D conversions: send bucket `to` at
/// step s while receiving from `rank - s`, then feed every received
/// (global row, global col start, values) segment to `place`.
fn exchange_segments(
    mesh: &mut Mesh,
    mut buckets: Vec<Writer>,
    counts: &[u32],
    mut place: impl FnMut(u64, u64, &[f64]) -> Result<()>,
) -> Result<()> {
    let p = mesh.size();
    let rank = mesh.rank();
    for s in 1..p {
        let to = (rank + s) % p;
        let from = (rank + p - s) % p;
        let mut payload = Writer::new();
        payload.put_u32(counts[to]);
        let body = std::mem::take(&mut buckets[to]).into_bytes();
        payload.reserve(body.len());
        let mut full = payload.into_bytes();
        full.extend_from_slice(&body);
        let got = mesh.exchange(to, &full, from)?;
        let mut r = Reader::new(&got);
        let n = r.get_u32()?;
        for _ in 0..n {
            let gr = r.get_u64()?;
            let j0 = r.get_u64()?;
            let vals = r.get_f64_slice()?;
            place(gr, j0, &vals)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_mesh;
    use crate::elemental::panel::{gather_matrix, scatter_matrix};
    use crate::linalg::DenseMatrix;
    use crate::workload::random_matrix;
    use std::sync::Arc;

    fn run_redistribution(rows: u64, cols: u64, p: usize, from: LayoutKind, to: LayoutKind) {
        let meta = MatrixMeta {
            handle: 1,
            rows,
            cols,
            layout: LayoutDesc { kind: from, owners: (0..p as u32).collect() },
        };
        let full =
            DenseMatrix::from_vec(rows as usize, cols as usize, random_matrix(3, rows as usize, cols as usize))
                .unwrap();
        let panels = Arc::new(scatter_matrix(&meta, &full).unwrap());
        let panels2 = panels.clone();
        let out = run_mesh(p, move |mut mesh| {
            let mine = panels2[mesh.rank()].clone();
            redistribute(&mut mesh, &mine, 2, to)
        })
        .unwrap();
        let back = gather_matrix(&out).unwrap();
        assert_eq!(back, full, "{from:?} -> {to:?} p={p}");
        assert_eq!(out[0].meta.layout.kind, to);
        assert_eq!(out[0].meta.handle, 2);
    }

    #[test]
    fn block_to_cyclic_and_back() {
        run_redistribution(23, 3, 3, LayoutKind::RowBlock, LayoutKind::RowCyclic);
        run_redistribution(23, 3, 3, LayoutKind::RowCyclic, LayoutKind::RowBlock);
    }

    #[test]
    fn identity_redistribution() {
        run_redistribution(16, 2, 4, LayoutKind::RowBlock, LayoutKind::RowBlock);
    }

    #[test]
    fn single_worker() {
        run_redistribution(9, 2, 1, LayoutKind::RowBlock, LayoutKind::RowCyclic);
    }

    #[test]
    fn uneven_rows() {
        run_redistribution(17, 5, 4, LayoutKind::RowBlock, LayoutKind::RowCyclic);
    }

    use crate::elemental::layout::Grid;

    /// Scatter RowBlock → 2D, check every local element against the full
    /// matrix through the distribution maps, then gather back and demand
    /// bitwise identity (redistribution must be a pure permutation).
    fn roundtrip_2d(rows: u64, cols: u64, dist_of: impl Fn(Grid) -> BlockCyclic2D, p_r: u32, p_c: u32) {
        let p = (p_r * p_c) as usize;
        let meta = MatrixMeta {
            handle: 1,
            rows,
            cols,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: (0..p as u32).collect() },
        };
        let full = DenseMatrix::from_vec(
            rows as usize,
            cols as usize,
            random_matrix(11, rows as usize, cols as usize),
        )
        .unwrap();
        let panels = Arc::new(scatter_matrix(&meta, &full).unwrap());
        let dist = dist_of(Grid::new(p_r, p_c).unwrap());
        let full2 = full.clone();
        let meta2 = meta.clone();
        let out = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank() as u32;
            let local = rowblock_to_grid(&mut mesh, &panels[rank as usize], &dist)?;
            let (my_r, my_c) = (dist.grid.row_of(rank), dist.grid.col_of(rank));
            assert_eq!(
                local.shape(),
                (dist.local_rows(my_r) as usize, dist.local_cols(my_c) as usize)
            );
            for li in 0..local.rows() {
                for lj in 0..local.cols() {
                    let (i, j) = (
                        dist.global_row(my_r, li as u64),
                        dist.global_col(my_c, lj as u64),
                    );
                    assert_eq!(
                        local.get(li, lj),
                        full2.get(i as usize, j as usize),
                        "rank {rank} ({li},{lj}) <- ({i},{j})"
                    );
                }
            }
            let back_meta = MatrixMeta { handle: 2, ..meta2.clone() };
            grid_to_rowblock(&mut mesh, &local, &dist, back_meta)
        })
        .unwrap();
        let back = gather_matrix(&out).unwrap();
        assert_eq!(back, full, "{p_r}x{p_c} {rows}x{cols}");
        assert_eq!(out[0].meta.handle, 2);
    }

    #[test]
    fn rowblock_to_grid_and_back_pure_block() {
        for (p_r, p_c) in [(1u32, 1u32), (2, 2), (3, 2), (1, 4), (4, 1)] {
            for (rows, cols) in [(17u64, 9u64), (5, 13), (3, 3)] {
                roundtrip_2d(rows, cols, |g| BlockCyclic2D::blocked(g, rows, cols), p_r, p_c);
            }
        }
    }

    #[test]
    fn rowblock_to_grid_and_back_block_cyclic() {
        // narrow cyclic blocks (the SUMMA A/B shapes) and ragged tails
        for (p_r, p_c) in [(2u32, 2u32), (3, 2), (2, 3)] {
            roundtrip_2d(17, 11, |g| BlockCyclic2D::new(g, 17, 11, 3, 2).unwrap(), p_r, p_c);
            roundtrip_2d(7, 19, |g| BlockCyclic2D::new(g, 7, 19, 1, 4).unwrap(), p_r, p_c);
        }
    }

    #[test]
    fn grid_conversions_handle_empty_and_tiny() {
        // degenerate extents: fewer rows/cols than grid dimensions, and
        // empty matrices
        roundtrip_2d(1, 1, |g| BlockCyclic2D::blocked(g, 1, 1), 2, 2);
        roundtrip_2d(0, 4, |g| BlockCyclic2D::blocked(g, 0, 4), 2, 2);
        roundtrip_2d(4, 0, |g| BlockCyclic2D::blocked(g, 4, 0), 2, 2);
        roundtrip_2d(2, 3, |g| BlockCyclic2D::blocked(g, 2, 3), 3, 2);
    }

    #[test]
    fn grid_conversion_shape_errors() {
        let meta = MatrixMeta {
            handle: 1,
            rows: 6,
            cols: 4,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: vec![0, 1] },
        };
        let full = DenseMatrix::from_vec(6, 4, random_matrix(1, 6, 4)).unwrap();
        let panels = Arc::new(scatter_matrix(&meta, &full).unwrap());
        run_mesh(2, move |mut mesh| {
            // wrong grid size for the mesh
            let bad = BlockCyclic2D::blocked(Grid::new(2, 2).unwrap(), 6, 4);
            assert!(rowblock_to_grid(&mut mesh, &panels[mesh.rank()], &bad).is_err());
            // wrong matrix extent
            let wrong = BlockCyclic2D::blocked(Grid::new(2, 1).unwrap(), 7, 4);
            assert!(rowblock_to_grid(&mut mesh, &panels[mesh.rank()], &wrong).is_err());
            Ok(())
        })
        .unwrap();
    }
}
