//! Truncated SVD on top of the Lanczos eigensolver.
//!
//! A = U Σ Vᵀ, rank-k: run [`lanczos_topk`] on the Gram operator G = AᵀA
//! (σᵢ = √θᵢ, V = Ritz vectors), then recover U = A V Σ⁻¹. The local
//! variant here is the single-node reference (tests, sparklet executors);
//! the distributed variant lives in `ali::elemlib` where the Gram operator
//! applies across worker panels with an all-reduce per iteration.

use crate::arpack::{lanczos_topk, LanczosOptions, LocalGramOp};
use crate::linalg::DenseMatrix;
use crate::{Error, Result};

/// Truncated SVD result (local, fully materialized).
#[derive(Debug, Clone)]
pub struct TsvdResult {
    /// Top-k singular values, descending.
    pub singular_values: Vec<f64>,
    /// Left singular vectors, m x k.
    pub u: DenseMatrix,
    /// Right singular vectors, n x k.
    pub v: DenseMatrix,
    /// Gram-operator applications (the distributed cost unit).
    pub matvecs: usize,
}

/// Rank-k truncated SVD of a local dense matrix.
pub fn truncated_svd_local(a: &DenseMatrix, k: usize, opts: &LanczosOptions) -> Result<TsvdResult> {
    let (m, n) = a.shape();
    if k == 0 || k > n.min(m) {
        return Err(Error::Numerical(format!("tsvd: k={k} out of range for {m}x{n}")));
    }
    let mut op = LocalGramOp::new(a);
    let r = lanczos_topk(&mut op, k, opts)?;
    let matvecs = r.matvecs;

    let mut singular_values = Vec::with_capacity(k);
    let mut v = DenseMatrix::zeros(n, k);
    for (j, (theta, vec)) in r.eigenvalues.iter().zip(&r.eigenvectors).enumerate() {
        singular_values.push(theta.max(0.0).sqrt());
        for i in 0..n {
            v.set(i, j, vec[i]);
        }
    }

    // U = A V Σ⁻¹ (columns with σ ~ 0 are zeroed — rank deficiency).
    let av = crate::linalg::gemm::gemm(a, &v)?;
    let mut u = DenseMatrix::zeros(m, k);
    for j in 0..k {
        let s = singular_values[j];
        if s > 1e-12 {
            for i in 0..m {
                u.set(i, j, av.get(i, j) / s);
            }
        }
    }
    Ok(TsvdResult { singular_values, u, v, matvecs })
}

/// Reconstruction error ‖A - U Σ Vᵀ‖_F of a truncated SVD — used by tests
/// and the e2e example to certify results against theory.
pub fn reconstruction_error(a: &DenseMatrix, r: &TsvdResult) -> Result<f64> {
    let k = r.singular_values.len();
    let (m, n) = a.shape();
    let mut usv = DenseMatrix::zeros(m, n);
    for j in 0..k {
        let s = r.singular_values[j];
        for i in 0..m {
            let uis = r.u.get(i, j) * s;
            if uis == 0.0 {
                continue;
            }
            for l in 0..n {
                let cur = usv.get(i, l);
                usv.set(i, l, cur + uis * r.v.get(l, j));
            }
        }
    }
    let mut diff = 0.0;
    for i in 0..m {
        for j in 0..n {
            let d = a.get(i, j) - usv.get(i, j);
            diff += d * d;
        }
    }
    Ok(diff.sqrt())
}

/// Condition-number estimate via the extreme Ritz values of the Gram
/// operator — the paper's hypothetical `condest` library routine (§3.3).
/// This is an *estimate*: Ritz values bound the spectrum from inside.
pub fn condest(a: &DenseMatrix, probes: usize, opts: &LanczosOptions) -> Result<f64> {
    let n = a.cols();
    let k = probes.clamp(2, n);
    let mut op = LocalGramOp::new(a);
    // Large basis improves the smallest-Ritz-value estimate.
    let opts = LanczosOptions { max_basis: (4 * k + 20).min(n), ..opts.clone() };
    let r = lanczos_topk(&mut op, k.min(n), &opts)?;
    let smax = r.eigenvalues.first().copied().unwrap_or(0.0).max(0.0).sqrt();
    // Ritz from the *bottom* of the spectrum: rerun on shifted operator
    // would be better; we use the smallest returned Ritz value as a
    // (biased) proxy, which is what cheap condition estimators do.
    let smin = r.eigenvalues.last().copied().unwrap_or(0.0).max(0.0).sqrt();
    if smin <= 1e-300 {
        return Ok(f64::INFINITY);
    }
    Ok(smax / smin)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gemm, gemm_tn};
    use crate::linalg::symeig::sym_eig;
    use crate::workload::{random_matrix, spectral_row};

    fn rand(seed: u64, m: usize, n: usize) -> DenseMatrix {
        DenseMatrix::from_vec(m, n, random_matrix(seed, m, n)).unwrap()
    }

    #[test]
    fn singular_values_match_dense_gram_eig() {
        let a = rand(1, 150, 30);
        let r = truncated_svd_local(&a, 8, &LanczosOptions::default()).unwrap();
        let ata = gemm_tn(&a, &a).unwrap();
        let (vals, _) = sym_eig(&ata).unwrap();
        for i in 0..8 {
            let want = vals[30 - 1 - i].max(0.0).sqrt();
            assert!(
                (r.singular_values[i] - want).abs() < 1e-7 * (1.0 + want),
                "i={i}: {} vs {want}",
                r.singular_values[i]
            );
        }
    }

    #[test]
    fn factors_are_orthonormal() {
        let a = rand(2, 100, 20);
        let r = truncated_svd_local(&a, 5, &LanczosOptions::default()).unwrap();
        let utu = gemm_tn(&r.u, &r.u).unwrap();
        let vtv = gemm_tn(&r.v, &r.v).unwrap();
        assert!(utu.max_abs_diff(&DenseMatrix::identity(5)).unwrap() < 1e-7);
        assert!(vtv.max_abs_diff(&DenseMatrix::identity(5)).unwrap() < 1e-7);
    }

    #[test]
    fn reconstruction_error_matches_tail_energy() {
        // For k = min(m,n), reconstruction is exact.
        let a = rand(3, 40, 10);
        let r = truncated_svd_local(&a, 10, &LanczosOptions::default()).unwrap();
        assert!(reconstruction_error(&a, &r).unwrap() < 1e-7);
        // For k < rank, error^2 = sum of discarded sigma^2.
        let r5 = truncated_svd_local(&a, 5, &LanczosOptions::default()).unwrap();
        let tail: f64 = r.singular_values[5..].iter().map(|s| s * s).sum();
        let err = reconstruction_error(&a, &r5).unwrap();
        assert!((err - tail.sqrt()).abs() < 1e-6, "{err} vs {}", tail.sqrt());
    }

    #[test]
    fn decaying_spectrum_converges_fast() {
        let (m, n) = (400, 64);
        let mut data = Vec::with_capacity(m * n);
        for i in 0..m {
            data.extend_from_slice(&spectral_row(9, i as u64, n, 0.85));
        }
        let a = DenseMatrix::from_vec(m, n, data).unwrap();
        let r = truncated_svd_local(&a, 10, &LanczosOptions::default()).unwrap();
        // descending and strictly positive head
        for w in r.singular_values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(r.singular_values[0] > r.singular_values[9]);
        // Av = sigma * u holds
        let av = gemm(&a, &r.v).unwrap();
        for j in 0..10 {
            for i in 0..m {
                let want = r.singular_values[j] * r.u.get(i, j);
                assert!((av.get(i, j) - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn condest_of_identity_is_one() {
        let a = DenseMatrix::identity(16);
        let c = condest(&a, 4, &LanczosOptions::default()).unwrap();
        assert!((c - 1.0).abs() < 1e-6, "condest {c}");
    }

    #[test]
    fn condest_scales_with_anisotropy() {
        // diag(10, 1...) => cond ~ 10
        let n = 12;
        let a = DenseMatrix::from_fn(n, n, |i, j| {
            if i != j {
                0.0
            } else if i == 0 {
                10.0
            } else {
                1.0
            }
        });
        let c = condest(&a, n, &LanczosOptions::default()).unwrap();
        assert!((c - 10.0).abs() < 1e-5, "condest {c}");
    }

    #[test]
    fn bad_k_rejected() {
        let a = rand(4, 10, 5);
        assert!(truncated_svd_local(&a, 0, &LanczosOptions::default()).is_err());
        assert!(truncated_svd_local(&a, 6, &LanczosOptions::default()).is_err());
    }
}
