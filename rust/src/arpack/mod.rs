//! ARPACK substitute: thick-restart Lanczos for the top-k eigenpairs of a
//! symmetric positive semi-definite operator, and the truncated SVD built
//! on it.
//!
//! The paper's §4.2 experiment runs "our own MPI-based implementation of
//! the truncated SVD using ARPACK and Elemental" on the Alchemist side and
//! MLlib's `computeSVD` (also ARPACK on the Gram operator) on the Spark
//! side. We mirror that exactly: [`lanczos::lanczos_topk`] is generic over
//! [`SymOp`], and *both* sides of our bridge drive the same algorithm —
//! the Alchemist path applies the operator with distributed panels and a
//! ring all-reduce per iteration, the sparklet path applies it with a
//! scheduled aggregation stage per iteration (which is precisely where
//! Spark's overheads bite).

pub mod lanczos;
pub mod svd;

use crate::Result;

/// A symmetric linear operator w = Op(v).
pub trait SymOp {
    /// Operator dimension n.
    fn dim(&self) -> usize;
    /// Apply the operator. Must be symmetric PSD for the SVD use.
    fn apply(&mut self, v: &[f64]) -> Result<Vec<f64>>;
}

/// Dense symmetric matrix as an operator (tests / small problems).
pub struct DenseSymOp<'a> {
    pub a: &'a crate::linalg::DenseMatrix,
}

impl SymOp for DenseSymOp<'_> {
    fn dim(&self) -> usize {
        self.a.rows()
    }

    fn apply(&mut self, v: &[f64]) -> Result<Vec<f64>> {
        self.a.matvec(v)
    }
}

/// Gram operator AᵀA of a local dense matrix.
pub struct LocalGramOp<'a> {
    pub a: &'a crate::linalg::DenseMatrix,
    /// matvec counter (benches/tests assert on iteration economy).
    pub applications: usize,
}

impl<'a> LocalGramOp<'a> {
    pub fn new(a: &'a crate::linalg::DenseMatrix) -> Self {
        LocalGramOp { a, applications: 0 }
    }
}

impl SymOp for LocalGramOp<'_> {
    fn dim(&self) -> usize {
        self.a.cols()
    }

    fn apply(&mut self, v: &[f64]) -> Result<Vec<f64>> {
        self.applications += 1;
        let t = self.a.matvec(v)?;
        self.a.matvec_t(&t)
    }
}

pub use lanczos::{lanczos_topk, LanczosOptions, LanczosResult};
pub use svd::{truncated_svd_local, TsvdResult};
