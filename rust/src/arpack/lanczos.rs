//! Thick-restart Lanczos (TRLan/ARPACK-style implicit restarting) with
//! full reorthogonalization, for the top-k eigenpairs of a symmetric
//! operator.
//!
//! The restarted projection matrix T is "arrowhead + tridiagonal" —
//! diag(kept Ritz values) coupled to the first new Lanczos vector — so
//! the inner solve uses the dense symmetric eigensolver
//! (`linalg::symeig`), exactly as TRLan does.

use crate::arpack::SymOp;
use crate::linalg::{blas1, qr::mgs_orthonormalize, symeig::sym_eig, DenseMatrix};
use crate::workload::Rng;
use crate::{Error, Result};

/// Options for [`lanczos_topk`].
#[derive(Debug, Clone)]
pub struct LanczosOptions {
    /// Residual tolerance relative to |theta| (ARPACK default regime).
    pub tol: f64,
    /// Max basis size before a restart; 0 = auto (max(2k+10, 30), capped
    /// at n).
    pub max_basis: usize,
    /// Max number of restarts before giving up.
    pub max_restarts: usize,
    /// RNG seed for the start vector.
    pub seed: u64,
}

impl Default for LanczosOptions {
    fn default() -> Self {
        LanczosOptions { tol: 1e-10, max_basis: 0, max_restarts: 200, seed: 17 }
    }
}

/// Result of a top-k symmetric eigensolve.
#[derive(Debug, Clone)]
pub struct LanczosResult {
    /// Top-k eigenvalues, descending.
    pub eigenvalues: Vec<f64>,
    /// Matching eigenvectors, each length n.
    pub eigenvectors: Vec<Vec<f64>>,
    /// Total operator applications (the distributed-cost unit).
    pub matvecs: usize,
    /// Number of thick restarts performed.
    pub restarts: usize,
}

/// Compute the k algebraically largest eigenpairs of `op`.
pub fn lanczos_topk(
    op: &mut dyn SymOp,
    k: usize,
    opts: &LanczosOptions,
) -> Result<LanczosResult> {
    let n = op.dim();
    if k == 0 || k > n {
        return Err(Error::Numerical(format!("lanczos: k={k} out of range for n={n}")));
    }
    let mb = if opts.max_basis == 0 {
        (2 * k + 10).max(30).min(n)
    } else {
        opts.max_basis.max(k + 2).min(n)
    };

    let mut rng = Rng::new(opts.seed);
    let mut matvecs = 0usize;
    let mut restarts = 0usize;

    // Basis vectors (columns), all length n, kept orthonormal. During a
    // cycle the basis holds `mb` columns plus the residual direction.
    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(mb + 1);
    // Projection matrix T in that basis (leading mb x mb block used).
    let mut t = DenseMatrix::zeros(mb, mb);
    // Locked/kept directions at the start of the current cycle.
    let mut l = 0usize;
    // beta coupling the last basis column to the residual direction.
    let mut last_beta = 0.0f64;

    let mut v0: Vec<f64> = (0..n).map(|_| rng.next_signed()).collect();
    if blas1::normalize(&mut v0) == 0.0 {
        return Err(Error::Numerical("lanczos: zero start vector".into()));
    }
    basis.push(v0);

    loop {
        // ---- Lanczos expansion from column l to mb-1 ----
        // Invariant entering the loop: basis has j+1 columns when
        // expanding column j (the j-th is the newest direction).
        let mut cycle_len = mb; // may shrink on irrecoverable breakdown
        for j in l..mb {
            let w_in = basis[j].clone();
            let mut w = op.apply(&w_in)?;
            matvecs += 1;
            if w.len() != n {
                return Err(Error::Numerical("lanczos: operator changed dimension".into()));
            }
            let alpha = blas1::dot(&w, &basis[j]);
            t.set(j, j, alpha);
            // Full reorthogonalization (MGS, twice) against the whole
            // basis removes the alpha/beta/coupling components and keeps
            // the basis numerically orthonormal.
            let mut beta = mgs_orthonormalize(&mut w, &basis);
            if beta <= 1e-13 {
                // Breakdown: Krylov space invariant. Continue with a fresh
                // random direction orthogonal to the basis; the coupling
                // to the old space is zero.
                let mut fresh: Vec<f64> = (0..n).map(|_| rng.next_signed()).collect();
                let nrm = mgs_orthonormalize(&mut fresh, &basis);
                if nrm <= 1e-13 {
                    // Whole space spanned (n ~ basis size): stop the cycle.
                    cycle_len = j + 1;
                    last_beta = 0.0;
                    break;
                }
                w = fresh;
                beta = 0.0;
            }
            if j + 1 < mb {
                t.set(j + 1, j, beta);
                t.set(j, j + 1, beta);
            }
            last_beta = beta;
            basis.push(w);
        }
        let m = cycle_len;

        // ---- Rayleigh-Ritz on the leading m x m block ----
        let t_sub = DenseMatrix::from_fn(m, m, |i, j| t.get(i, j));
        let (vals, z) = sym_eig(&t_sub)?; // ascending
        let order: Vec<usize> = (0..m).rev().collect(); // descending

        let kk = k.min(m);
        let res = |i: usize| -> f64 { (last_beta * z.get(m - 1, order[i])).abs() };
        let all_converged = m == n
            || m < mb // breakdown cycle: space exhausted, results exact
            || (0..kk).all(|i| res(i) <= opts.tol * vals[order[i]].abs().max(f64::EPSILON));

        if all_converged || restarts >= opts.max_restarts {
            if !all_converged {
                return Err(Error::Numerical(format!(
                    "lanczos: no convergence after {restarts} restarts ({matvecs} matvecs)"
                )));
            }
            let mut eigenvalues = Vec::with_capacity(kk);
            let mut eigenvectors = Vec::with_capacity(kk);
            for i in 0..kk {
                eigenvalues.push(vals[order[i]]);
                eigenvectors.push(basis_times_col(&basis, &z, m, order[i], n));
            }
            return Ok(LanczosResult { eigenvalues, eigenvectors, matvecs, restarts });
        }

        // ---- Thick restart: keep the top `keep` Ritz pairs ----
        restarts += 1;
        let keep = (kk + (m - kk) / 2).min(m - 1);
        let mut new_basis: Vec<Vec<f64>> = Vec::with_capacity(mb + 1);
        for i in 0..keep {
            new_basis.push(basis_times_col(&basis, &z, m, order[i], n));
        }
        // The residual direction (basis column m) seeds the new cycle.
        new_basis.push(basis[m].clone());

        let mut new_t = DenseMatrix::zeros(mb, mb);
        for i in 0..keep {
            new_t.set(i, i, vals[order[i]]);
            let s = last_beta * z.get(m - 1, order[i]);
            new_t.set(keep, i, s);
            new_t.set(i, keep, s);
        }
        basis = new_basis;
        t = new_t;
        l = keep;
    }
}

/// y = Σ_j basis[j] * z[j, col] over the first m basis vectors.
fn basis_times_col(
    basis: &[Vec<f64>],
    z: &DenseMatrix,
    m: usize,
    col: usize,
    n: usize,
) -> Vec<f64> {
    let mut y = vec![0.0; n];
    for j in 0..m {
        blas1::axpy(z.get(j, col), &basis[j], &mut y);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arpack::{DenseSymOp, LocalGramOp};
    use crate::linalg::symeig::sym_eig as dense_eig;
    use crate::workload::Rng;

    fn random_symmetric(seed: u64, n: usize) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        let mut a = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.next_signed();
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        a
    }

    #[test]
    fn topk_matches_dense_eig() {
        for n in [12, 30, 80] {
            let a = random_symmetric(n as u64, n);
            let (full_vals, _) = dense_eig(&a).unwrap();
            let mut op = DenseSymOp { a: &a };
            let k = 5.min(n);
            let r = lanczos_topk(&mut op, k, &LanczosOptions::default()).unwrap();
            for i in 0..k {
                let want = full_vals[n - 1 - i];
                assert!(
                    (r.eigenvalues[i] - want).abs() < 1e-7 * (1.0 + want.abs()),
                    "n={n} i={i}: {} vs {want}",
                    r.eigenvalues[i]
                );
            }
            // eigenvector residuals ||A y - theta y||
            for i in 0..k {
                let y = &r.eigenvectors[i];
                let ay = a.matvec(y).unwrap();
                let mut res = ay.clone();
                blas1::axpy(-r.eigenvalues[i], y, &mut res);
                assert!(blas1::nrm2(&res) < 1e-6, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn restart_path_is_exercised() {
        // small basis forces restarts
        let n = 60;
        let a = random_symmetric(7, n);
        let (full_vals, _) = dense_eig(&a).unwrap();
        let mut op = DenseSymOp { a: &a };
        let opts = LanczosOptions { max_basis: 12, ..Default::default() };
        let r = lanczos_topk(&mut op, 4, &opts).unwrap();
        assert!(r.restarts > 0, "expected restarts with tiny basis");
        for i in 0..4 {
            let want = full_vals[n - 1 - i];
            assert!((r.eigenvalues[i] - want).abs() < 1e-7 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn gram_operator_gives_singular_values() {
        let m = 120;
        let n = 24;
        let a = DenseMatrix::from_vec(m, n, crate::workload::random_matrix(3, m, n)).unwrap();
        let mut op = LocalGramOp::new(&a);
        let r = lanczos_topk(&mut op, 6, &LanczosOptions::default()).unwrap();
        // reference: eigenvalues of dense AᵀA
        let ata = crate::linalg::gemm::gemm_tn(&a, &a).unwrap();
        let (vals, _) = dense_eig(&ata).unwrap();
        for i in 0..6 {
            let want = vals[n - 1 - i];
            assert!((r.eigenvalues[i] - want).abs() < 1e-7 * (1.0 + want), "i={i}");
        }
        assert!(op.applications > 0);
        assert_eq!(op.applications, r.matvecs);
    }

    #[test]
    fn exact_when_k_equals_n() {
        let n = 10;
        let a = random_symmetric(5, n);
        let (full_vals, _) = dense_eig(&a).unwrap();
        let mut op = DenseSymOp { a: &a };
        let r = lanczos_topk(&mut op, n, &LanczosOptions::default()).unwrap();
        for i in 0..n {
            assert!((r.eigenvalues[i] - full_vals[n - 1 - i]).abs() < 1e-8);
        }
    }

    #[test]
    fn low_rank_operator_breakdown_recovers() {
        // rank-2 PSD matrix: Lanczos breaks down after 2 steps; top-3
        // should come back as (lam1, lam2, ~0).
        let n = 16;
        let mut u1: Vec<f64> = (0..n).map(|i| (i as f64 + 1.0).sin()).collect();
        let mut u2: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).cos()).collect();
        blas1::normalize(&mut u1);
        let p = blas1::dot(&u1, &u2);
        blas1::axpy(-p, &u1, &mut u2);
        blas1::normalize(&mut u2);
        let a = DenseMatrix::from_fn(n, n, |i, j| 5.0 * u1[i] * u1[j] + 2.0 * u2[i] * u2[j]);
        let mut op = DenseSymOp { a: &a };
        let r = lanczos_topk(&mut op, 3, &LanczosOptions::default()).unwrap();
        assert!((r.eigenvalues[0] - 5.0).abs() < 1e-8);
        assert!((r.eigenvalues[1] - 2.0).abs() < 1e-8);
        assert!(r.eigenvalues[2].abs() < 1e-8);
    }

    #[test]
    fn invalid_k_rejected() {
        let a = random_symmetric(1, 5);
        let mut op = DenseSymOp { a: &a };
        assert!(lanczos_topk(&mut op, 0, &LanczosOptions::default()).is_err());
        assert!(lanczos_topk(&mut op, 6, &LanczosOptions::default()).is_err());
    }
}
