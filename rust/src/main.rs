//! `alchemist` — launcher CLI (the `Cori-start-alchemist.sh` analogue).
//!
//! ```text
//! alchemist serve  [--config FILE] [--set k=v]...   start a server, print its address
//! alchemist demo   [--config FILE] [--set k=v]...   end-to-end smoke demo
//! alchemist info   [--config FILE] [--set k=v]...   resolved config + artifact inventory
//! ```
//!
//! Argument parsing is hand-rolled (offline build; no clap) but follows
//! the same `--config` / `--set section.key=value` convention everywhere.

use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::LayoutKind;
use alchemist::runtime::PjrtRuntime;
use alchemist::server::start_server;
use alchemist::workload::random_matrix;

fn usage() -> ! {
    eprintln!(
        "usage: alchemist <serve|demo|info> [--config FILE] [--set section.key=value]..."
    );
    std::process::exit(2);
}

fn parse_args(args: &[String]) -> Result<(Option<String>, Vec<String>), String> {
    let mut config = None;
    let mut overrides = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                config = Some(args.get(i + 1).ok_or("--config needs a value")?.clone());
                i += 2;
            }
            "--set" => {
                overrides.push(args.get(i + 1).ok_or("--set needs key=value")?.clone());
                i += 2;
            }
            other => return Err(format!("unknown flag {other:?}")),
        }
    }
    Ok((config, overrides))
}

fn main() {
    alchemist::logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let (config_file, overrides) = match parse_args(&args[1..]) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
        }
    };
    let cfg = match Config::resolve(config_file.as_deref(), &overrides) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }
    };

    let result = match cmd.as_str() {
        "serve" => cmd_serve(&cfg),
        "demo" => cmd_demo(&cfg),
        "info" => cmd_info(&cfg),
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_serve(cfg: &Config) -> alchemist::Result<()> {
    let server = start_server(cfg)?;
    // Like the Cori script, publish the driver address for clients.
    println!("ALCHEMIST_DRIVER={}", server.driver_addr);
    println!("workers={} backend={}", cfg.server.workers, cfg.server.gemm_backend);
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_demo(cfg: &Config) -> alchemist::Result<()> {
    println!("starting server with {} workers...", cfg.server.workers);
    let server = start_server(cfg)?;
    let mut ac = AlchemistContext::connect(&server.driver_addr, "demo")?;
    ac.request_workers(cfg.server.workers)?;
    wrappers::register_elemlib(&ac)?;

    let a = DenseMatrix::from_vec(64, 16, random_matrix(1, 64, 16))?;
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock)?;
    let cond = wrappers::cond_est(&ac, &al_a)?;
    println!("condest(A) = {cond:.3}");
    let svd = wrappers::truncated_svd(&ac, &al_a, 4)?;
    let s = ac.fetch_dense(&svd.s)?;
    println!(
        "top-4 singular values: {:?} ({} gram matvecs)",
        (0..4).map(|i| (s.get(i, 0) * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
        svd.matvecs
    );
    ac.stop()?;
    server.shutdown();
    println!("demo OK");
    Ok(())
}

fn cmd_info(cfg: &Config) -> alchemist::Result<()> {
    println!("config: {cfg:#?}");
    match PjrtRuntime::find_artifacts_dir(&cfg.server.artifacts_dir) {
        Ok(dir) => {
            println!("artifacts dir: {}", dir.display());
            let mut names: Vec<String> = std::fs::read_dir(&dir)?
                .filter_map(|e| e.ok())
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .filter(|n| n.ends_with(".hlo.txt"))
                .collect();
            names.sort();
            for n in names {
                println!("  {n}");
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
    Ok(())
}
