//! Workload generation: seeded RNG + the random dense matrices the paper's
//! experiments use ("random dense matrices generated within Spark" — §4.1,
//! and the tall-skinny / short-wide 400 GB transfer matrices of §4.3).

/// SplitMix64 — tiny, fast, reproducible. Used everywhere a bench or test
/// needs deterministic "random" data.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [-1, 1) — matches "random dense" test matrices.
    pub fn next_signed(&mut self) -> f64 {
        2.0 * self.next_f64() - 1.0
    }

    /// Standard normal via Box-Muller (used for well-conditioned SVD
    /// test matrices).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn next_range(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }
}

/// Generate row `i` of a seeded random matrix without materializing the
/// whole matrix: each row is derived from (seed, i), so distributed
/// generators (sparklet partitions, per-worker panels) produce *the same
/// matrix* regardless of partitioning — which is what lets tests compare
/// results across the Spark path and the Alchemist path.
pub fn random_row(seed: u64, i: u64, cols: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ i.wrapping_mul(0xA24BAED4963EE407));
    (0..cols).map(|_| rng.next_signed()).collect()
}

/// Dense row-major random matrix.
pub fn random_matrix(seed: u64, rows: usize, cols: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(rows * cols);
    for i in 0..rows {
        out.extend_from_slice(&random_row(seed, i as u64, cols));
    }
    out
}

/// A matrix with a known, rapidly-decaying spectrum: A = G * diag(s),
/// where G is Gaussian and s_j = decay^j. With m >> n, the singular values
/// of A concentrate near sqrt(m) * s_j, giving the truncated-SVD benches
/// a spectrum where rank-k truncation is meaningful (as in PCA workloads
/// the paper motivates).
pub fn spectral_row(seed: u64, i: u64, cols: usize, decay: f64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ i.wrapping_mul(0x9FB21C651E98DF25));
    (0..cols).map(|j| rng.next_gaussian() * decay.powi(j as i32)).collect()
}

/// Paper experiment geometries (§4), scaled by ~2^10 for a laptop-class
/// testbed. Dimensions stay in the paper's aspect ratios.
pub mod geometries {
    /// Table 1 rows: (m, n, k) — the paper's dimensions (in thousands:
    /// (10,10,10), (50,10,30), (100,10,70), (300,10,60)) scaled by 1/16.
    pub const TABLE1: [(usize, usize, usize); 4] = [
        (625, 625, 625),
        (3_125, 625, 1_875),
        (6_250, 625, 4_375),
        (18_750, 625, 3_750),
    ];
    /// Paper node counts per Table 1 row.
    pub const TABLE1_NODES: [u32; 4] = [1, 1, 2, 4];

    /// Fig 3/4 SVD sweep: paper m in {312.5k, 625k, 1.25m, 2.5m, 5m},
    /// n = 10k, k = 20. Scaled /64: n = 156 -> round to 160.
    pub const SVD_N: usize = 512;
    pub const SVD_K: usize = 20;
    pub const SVD_M: [usize; 5] = [4_882, 9_765, 19_531, 39_062, 78_125];

    /// Tables 2/3: 400 GB matrices, tall 5.12M x 10k vs wide 40k x 1.28M.
    /// Scaled to ~100 MB keeping the 128x row-count ratio.
    pub const TALL: (usize, usize) = (131_072, 100); // 131k rows x 100
    pub const WIDE: (usize, usize) = (1_024, 12_800); // 1k rows x 12.8k
    /// Paper node grid (Tables 2/3): 8..56 step 8, total <= 64.
    pub const NODE_GRID: [u32; 7] = [8, 16, 24, 32, 40, 48, 56];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_uniform_range() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn rows_independent_of_partitioning() {
        // The core property: row i only depends on (seed, i).
        let full = random_matrix(42, 10, 8);
        for i in 0..10 {
            assert_eq!(&full[i * 8..(i + 1) * 8], random_row(42, i as u64, 8).as_slice());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(random_row(1, 0, 16), random_row(2, 0, 16));
        assert_ne!(random_row(1, 0, 16), random_row(1, 1, 16));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn spectral_rows_decay() {
        let row = spectral_row(5, 0, 32, 0.5);
        assert_eq!(row.len(), 32);
        // later columns should be tiny relative to early ones on average
        let early: f64 = row[..4].iter().map(|x| x.abs()).sum();
        let late: f64 = row[28..].iter().map(|x| x.abs()).sum();
        assert!(late < early);
    }
}
