//! Collective operations over a [`super::Mesh`].
//!
//! The distributed-GEMM and Lanczos paths only need a handful of MPI
//! collectives; we provide both a naive (root-funneled) and a ring
//! implementation of all-reduce — `ablate_collectives` measures the gap,
//! and the ring version is what the hot path uses (bandwidth-optimal for
//! the n-vector all-reduces each Lanczos iteration performs).

use super::Mesh;
use crate::linalg::blas1;
use crate::Result;

/// Which all-reduce algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllReduceAlgo {
    /// gather-to-0, reduce, broadcast. 2 rounds, root is the bottleneck.
    Naive,
    /// reduce-scatter + all-gather ring. 2(p-1) steps, each n/p sized.
    Ring,
}

/// Barrier: everyone checks in with rank 0, rank 0 releases everyone.
pub fn barrier(mesh: &mut Mesh) -> Result<()> {
    if mesh.size() == 1 {
        return Ok(());
    }
    if mesh.rank() == 0 {
        for r in 1..mesh.size() {
            mesh.recv(r)?;
        }
        for r in 1..mesh.size() {
            mesh.send(r, &[])?;
        }
    } else {
        mesh.send(0, &[])?;
        mesh.recv(0)?;
    }
    Ok(())
}

/// Broadcast `data` from `root` to every rank (binomial-tree).
pub fn broadcast(mesh: &mut Mesh, root: usize, data: &mut Vec<f64>) -> Result<()> {
    let p = mesh.size();
    if p == 1 {
        return Ok(());
    }
    // Re-index so root is virtual rank 0.
    let vrank = (mesh.rank() + p - root) % p;
    let mut mask = 1usize;
    // Receive phase: find our parent.
    while mask < p {
        if vrank & mask != 0 {
            let parent = (vrank - mask + root) % p;
            *data = mesh.recv_f64s(parent)?;
            break;
        }
        mask <<= 1;
    }
    // Send phase: forward to children below our lowest set bit.
    let mut child_mask = if vrank == 0 { largest_pow2_below(p) } else { mask >> 1 };
    while child_mask > 0 {
        let vchild = vrank | child_mask;
        if vchild < p && vchild != vrank {
            let child = (vchild + root) % p;
            mesh.send_f64s(child, data)?;
        }
        child_mask >>= 1;
    }
    Ok(())
}

fn largest_pow2_below(p: usize) -> usize {
    let mut m = 1;
    while m * 2 < p {
        m *= 2;
    }
    m
}

/// Gather per-rank vectors to `root`. Returns `Some(vec of per-rank data)`
/// on the root, `None` elsewhere.
pub fn gather(mesh: &mut Mesh, root: usize, data: &[f64]) -> Result<Option<Vec<Vec<f64>>>> {
    if mesh.rank() == root {
        let mut out: Vec<Vec<f64>> = vec![Vec::new(); mesh.size()];
        out[root] = data.to_vec();
        for r in 0..mesh.size() {
            if r != root {
                out[r] = mesh.recv_f64s(r)?;
            }
        }
        Ok(Some(out))
    } else {
        mesh.send_f64s(root, data)?;
        Ok(None)
    }
}

/// All-gather: every rank ends with every rank's vector (ring pass).
pub fn allgather(mesh: &mut Mesh, data: &[f64]) -> Result<Vec<Vec<f64>>> {
    let p = mesh.size();
    let mut out: Vec<Vec<f64>> = vec![Vec::new(); p];
    out[mesh.rank()] = data.to_vec();
    if p == 1 {
        return Ok(out);
    }
    let next = (mesh.rank() + 1) % p;
    let prev = (mesh.rank() + p - 1) % p;
    // p-1 ring steps; at step s we forward the block that originated at
    // rank (rank - s).
    for s in 0..p - 1 {
        let send_origin = (mesh.rank() + p - s) % p;
        let recv_origin = (prev + p - s) % p;
        // Deadlock-safe ordering: even ranks send first. With p >= 2 and a
        // ring, this alternation always pairs a sender with a receiver.
        if mesh.rank() % 2 == 0 {
            let buf = out[send_origin].clone();
            mesh.send_f64s(next, &buf)?;
            out[recv_origin] = mesh.recv_f64s(prev)?;
        } else {
            out[recv_origin] = mesh.recv_f64s(prev)?;
            let buf = out[send_origin].clone();
            mesh.send_f64s(next, &buf)?;
        }
    }
    Ok(out)
}

/// Sum-reduce to root. Returns the reduced vector on root, `None` elsewhere.
pub fn reduce_sum(mesh: &mut Mesh, root: usize, data: &[f64]) -> Result<Option<Vec<f64>>> {
    match gather(mesh, root, data)? {
        Some(parts) => {
            let mut acc = vec![0.0; data.len()];
            for part in parts {
                blas1::axpy(1.0, &part, &mut acc);
            }
            Ok(Some(acc))
        }
        None => Ok(None),
    }
}

/// All-reduce (sum) with the selected algorithm. `data` is reduced in place.
pub fn allreduce_sum(mesh: &mut Mesh, data: &mut Vec<f64>, algo: AllReduceAlgo) -> Result<()> {
    if mesh.size() == 1 {
        return Ok(());
    }
    match algo {
        AllReduceAlgo::Naive => {
            let reduced = reduce_sum(mesh, 0, data)?;
            let mut buf = reduced.unwrap_or_default();
            broadcast(mesh, 0, &mut buf)?;
            *data = buf;
            Ok(())
        }
        AllReduceAlgo::Ring => ring_allreduce(mesh, data),
    }
}

/// Bandwidth-optimal ring all-reduce: reduce-scatter then all-gather, with
/// the vector split into `p` chunks.
fn ring_allreduce(mesh: &mut Mesh, data: &mut [f64]) -> Result<()> {
    let p = mesh.size();
    let rank = mesh.rank();
    let n = data.len();
    let chunk = (n + p - 1) / p;
    let bounds =
        |c: usize| -> (usize, usize) { ((c * chunk).min(n), ((c + 1) * chunk).min(n)) };
    let next = (rank + 1) % p;
    let prev = (rank + p - 1) % p;

    // Phase 1: reduce-scatter. After p-1 steps, rank r owns the fully
    // reduced chunk (r + 1) % p.
    for s in 0..p - 1 {
        let send_c = (rank + p - s) % p;
        let recv_c = (prev + p - s) % p;
        let (s0, s1) = bounds(send_c);
        let (r0, r1) = bounds(recv_c);
        if rank % 2 == 0 {
            let buf = data[s0..s1].to_vec();
            mesh.send_f64s(next, &buf)?;
            let got = mesh.recv_f64s(prev)?;
            blas1::axpy(1.0, &got, &mut data[r0..r1]);
        } else {
            let got = mesh.recv_f64s(prev)?;
            let buf = data[s0..s1].to_vec();
            mesh.send_f64s(next, &buf)?;
            blas1::axpy(1.0, &got, &mut data[r0..r1]);
        }
    }

    // Phase 2: all-gather the reduced chunks around the ring.
    for s in 0..p - 1 {
        let send_c = (rank + 1 + p - s) % p;
        let recv_c = (rank + p - s) % p;
        let (s0, s1) = bounds(send_c);
        let (r0, r1) = bounds(recv_c);
        if rank % 2 == 0 {
            let buf = data[s0..s1].to_vec();
            mesh.send_f64s(next, &buf)?;
            let got = mesh.recv_f64s(prev)?;
            data[r0..r1].copy_from_slice(&got);
        } else {
            let got = mesh.recv_f64s(prev)?;
            let buf = data[s0..s1].to_vec();
            mesh.send_f64s(next, &buf)?;
            data[r0..r1].copy_from_slice(&got);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_mesh;

    #[test]
    fn barrier_completes() {
        run_mesh(5, |mut mesh| barrier(&mut mesh)).unwrap();
    }

    #[test]
    fn broadcast_from_each_root() {
        for root in 0..4 {
            let results = run_mesh(4, move |mut mesh| {
                let mut data = if mesh.rank() == root {
                    vec![1.0, 2.0, 3.0, root as f64]
                } else {
                    vec![]
                };
                broadcast(&mut mesh, root, &mut data)?;
                Ok(data)
            })
            .unwrap();
            for r in results {
                assert_eq!(r, vec![1.0, 2.0, 3.0, root as f64]);
            }
        }
    }

    #[test]
    fn gather_collects_in_rank_order() {
        let results = run_mesh(3, |mut mesh| {
            let mine = vec![mesh.rank() as f64; mesh.rank() + 1];
            gather(&mut mesh, 0, &mine)
        })
        .unwrap();
        let root = results[0].as_ref().unwrap();
        assert_eq!(root[0], vec![0.0]);
        assert_eq!(root[1], vec![1.0, 1.0]);
        assert_eq!(root[2], vec![2.0, 2.0, 2.0]);
        assert!(results[1].is_none() && results[2].is_none());
    }

    #[test]
    fn allgather_everyone_gets_everything() {
        let results = run_mesh(4, |mut mesh| {
            let mine = vec![mesh.rank() as f64 * 10.0];
            allgather(&mut mesh, &mine)
        })
        .unwrap();
        for r in &results {
            for (j, part) in r.iter().enumerate() {
                assert_eq!(part, &vec![j as f64 * 10.0]);
            }
        }
    }

    #[test]
    fn allreduce_both_algorithms_match() {
        for algo in [AllReduceAlgo::Naive, AllReduceAlgo::Ring] {
            for p in [1, 2, 3, 4, 7] {
                let results = run_mesh(p, move |mut mesh| {
                    // vector length deliberately not divisible by p
                    let mut data: Vec<f64> =
                        (0..10).map(|i| (mesh.rank() * 100 + i) as f64).collect();
                    allreduce_sum(&mut mesh, &mut data, algo)?;
                    Ok(data)
                })
                .unwrap();
                let want: Vec<f64> = (0..10)
                    .map(|i| (0..p).map(|r| (r * 100 + i) as f64).sum())
                    .collect();
                for r in &results {
                    assert_eq!(r, &want, "algo {algo:?} p {p}");
                }
            }
        }
    }

    #[test]
    fn reduce_sum_only_root_has_result() {
        let results = run_mesh(3, |mut mesh| {
            let data = vec![1.0, 2.0];
            reduce_sum(&mut mesh, 1, &data)
        })
        .unwrap();
        assert!(results[0].is_none());
        assert_eq!(results[1].as_ref().unwrap(), &vec![3.0, 6.0]);
        assert!(results[2].is_none());
    }
}
