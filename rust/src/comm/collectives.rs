//! Collective operations over a [`super::Mesh`].
//!
//! The distributed-GEMM and Lanczos paths only need a handful of MPI
//! collectives; we provide both a naive (root-funneled) and a ring
//! implementation of all-reduce — `ablate_collectives` measures the gap,
//! and the ring version is what the hot path uses (bandwidth-optimal for
//! the n-vector all-reduces each Lanczos iteration performs).

use super::Mesh;
use crate::linalg::{blas1, DenseMatrix};
use crate::{Error, Result};

/// Which all-reduce algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllReduceAlgo {
    /// gather-to-0, reduce, broadcast. 2 rounds, root is the bottleneck.
    Naive,
    /// reduce-scatter + all-gather ring. 2(p-1) steps, each n/p sized.
    Ring,
}

/// Dissemination barrier: ⌈log₂ p⌉ rounds; in round k every rank sends an
/// empty frame to `(rank + 2^k) % p` and receives one from
/// `(rank - 2^k) % p`. Replaces the root-funneled barrier (2(p−1)
/// sequential messages through rank 0) with log-depth all-to-all
/// progress — no rank is a bottleneck.
pub fn barrier(mesh: &mut Mesh) -> Result<()> {
    let p = mesh.size();
    if p == 1 {
        return Ok(());
    }
    let rank = mesh.rank();
    let mut d = 1usize;
    while d < p {
        let to = (rank + d) % p;
        let from = (rank + p - d) % p;
        // Empty frames always fit the kernel socket buffer, so the
        // blocking send cannot jam against the matching recv.
        mesh.send(to, &[])?;
        mesh.recv(from)?;
        d *= 2;
    }
    Ok(())
}

/// Broadcast `data` from `root` to every rank (binomial-tree).
pub fn broadcast(mesh: &mut Mesh, root: usize, data: &mut Vec<f64>) -> Result<()> {
    let p = mesh.size();
    if p == 1 {
        return Ok(());
    }
    // Re-index so root is virtual rank 0.
    let vrank = (mesh.rank() + p - root) % p;
    let mut mask = 1usize;
    // Receive phase: find our parent.
    while mask < p {
        if vrank & mask != 0 {
            let parent = (vrank - mask + root) % p;
            *data = mesh.recv_f64s(parent)?;
            break;
        }
        mask <<= 1;
    }
    // Send phase: forward to children below our lowest set bit.
    let mut child_mask = if vrank == 0 { largest_pow2_below(p) } else { mask >> 1 };
    while child_mask > 0 {
        let vchild = vrank | child_mask;
        if vchild < p && vchild != vrank {
            let child = (vchild + root) % p;
            mesh.send_f64s(child, data)?;
        }
        child_mask >>= 1;
    }
    Ok(())
}

fn largest_pow2_below(p: usize) -> usize {
    let mut m = 1;
    while m * 2 < p {
        m *= 2;
    }
    m
}

/// Gather per-rank vectors to `root`. Returns `Some(vec of per-rank data)`
/// on the root, `None` elsewhere.
pub fn gather(mesh: &mut Mesh, root: usize, data: &[f64]) -> Result<Option<Vec<Vec<f64>>>> {
    if mesh.rank() == root {
        let mut out: Vec<Vec<f64>> = vec![Vec::new(); mesh.size()];
        out[root] = data.to_vec();
        for r in 0..mesh.size() {
            if r != root {
                out[r] = mesh.recv_f64s(r)?;
            }
        }
        Ok(Some(out))
    } else {
        mesh.send_f64s(root, data)?;
        Ok(None)
    }
}

/// All-gather: every rank ends with every rank's vector (ring pass).
pub fn allgather(mesh: &mut Mesh, data: &[f64]) -> Result<Vec<Vec<f64>>> {
    let p = mesh.size();
    let mut out: Vec<Vec<f64>> = vec![Vec::new(); p];
    out[mesh.rank()] = data.to_vec();
    if p == 1 {
        return Ok(out);
    }
    let next = (mesh.rank() + 1) % p;
    let prev = (mesh.rank() + p - 1) % p;
    // p-1 ring steps; at step s we forward the block that originated at
    // rank (rank - s).
    for s in 0..p - 1 {
        let send_origin = (mesh.rank() + p - s) % p;
        let recv_origin = (prev + p - s) % p;
        // Deadlock-safe ordering: even ranks send first. With p >= 2 and a
        // ring, this alternation always pairs a sender with a receiver.
        if mesh.rank() % 2 == 0 {
            mesh.send_f64s(next, &out[send_origin])?;
            out[recv_origin] = mesh.recv_f64s(prev)?;
        } else {
            out[recv_origin] = mesh.recv_f64s(prev)?;
            mesh.send_f64s(next, &out[send_origin])?;
        }
    }
    Ok(out)
}

/// All-gather with known per-rank element counts, assembled directly into
/// one flat pre-sized buffer laid out in rank order (`counts[r]` elements
/// at offset `counts[..r].sum()`). This is the matrix all-gather hot path:
/// no `Vec<Vec<f64>>`, no re-concatenation — every received block lands
/// in its final position via `recv_f64s_into`.
pub fn allgather_flat(mesh: &mut Mesh, mine: &[f64], counts: &[usize]) -> Result<Vec<f64>> {
    let p = mesh.size();
    if counts.len() != p {
        return Err(Error::Protocol(format!(
            "allgather_flat: {} counts for {p} ranks",
            counts.len()
        )));
    }
    let rank = mesh.rank();
    if counts[rank] != mine.len() {
        return Err(Error::Protocol(format!(
            "allgather_flat: rank {rank} holds {} elements, counts say {}",
            mine.len(),
            counts[rank]
        )));
    }
    let offsets: Vec<usize> = counts
        .iter()
        .scan(0usize, |acc, &c| {
            let o = *acc;
            *acc += c;
            Some(o)
        })
        .collect();
    let total: usize = counts.iter().sum();
    let mut flat = vec![0.0f64; total];
    flat[offsets[rank]..offsets[rank] + mine.len()].copy_from_slice(mine);
    if p == 1 {
        return Ok(flat);
    }
    let next = (rank + 1) % p;
    let prev = (rank + p - 1) % p;
    // Same ring walk as `allgather`: at step s we forward the block that
    // originated at rank (rank - s), receiving origin (prev - s).
    for s in 0..p - 1 {
        let send_origin = (rank + p - s) % p;
        let recv_origin = (prev + p - s) % p;
        let (s0, s1) = (offsets[send_origin], offsets[send_origin] + counts[send_origin]);
        let (r0, r1) = (offsets[recv_origin], offsets[recv_origin] + counts[recv_origin]);
        // Deadlock-safe ordering: even ranks send first (p >= 2 always
        // pairs a sender with a receiver around the ring).
        if rank % 2 == 0 {
            mesh.send_f64s(next, &flat[s0..s1])?;
            mesh.recv_f64s_into(prev, &mut flat[r0..r1])?;
        } else {
            mesh.recv_f64s_into(prev, &mut flat[r0..r1])?;
            mesh.send_f64s(next, &flat[s0..s1])?;
        }
    }
    Ok(flat)
}

/// Sum-reduce to root. Returns the reduced vector on root, `None` elsewhere.
pub fn reduce_sum(mesh: &mut Mesh, root: usize, data: &[f64]) -> Result<Option<Vec<f64>>> {
    match gather(mesh, root, data)? {
        Some(parts) => {
            let mut acc = vec![0.0; data.len()];
            for part in parts {
                blas1::axpy(1.0, &part, &mut acc);
            }
            Ok(Some(acc))
        }
        None => Ok(None),
    }
}

/// Collective boolean OR: true on *every* rank iff `flag` is true on at
/// least one. The agreement step of cooperative cancellation — each rank
/// contributes its local cancel flag, and all ranks abort at the same
/// iteration or none does (see `ali::task`). One scalar ring all-reduce.
pub fn allreduce_flag(mesh: &mut Mesh, flag: bool) -> Result<bool> {
    let mut buf = vec![if flag { 1.0 } else { 0.0 }];
    allreduce_sum(mesh, &mut buf, AllReduceAlgo::Ring)?;
    Ok(buf[0] > 0.0)
}

/// All-reduce (sum) with the selected algorithm. `data` is reduced in place.
pub fn allreduce_sum(mesh: &mut Mesh, data: &mut Vec<f64>, algo: AllReduceAlgo) -> Result<()> {
    if mesh.size() == 1 {
        return Ok(());
    }
    match algo {
        AllReduceAlgo::Naive => {
            let reduced = reduce_sum(mesh, 0, data)?;
            let mut buf = reduced.unwrap_or_default();
            broadcast(mesh, 0, &mut buf)?;
            *data = buf;
            Ok(())
        }
        AllReduceAlgo::Ring => ring_allreduce(mesh, data),
    }
}

/// Bandwidth-optimal ring all-reduce: reduce-scatter then all-gather, with
/// the vector split into `p` chunks.
fn ring_allreduce(mesh: &mut Mesh, data: &mut [f64]) -> Result<()> {
    let p = mesh.size();
    let rank = mesh.rank();
    let n = data.len();
    let chunk = (n + p - 1) / p;
    let bounds =
        |c: usize| -> (usize, usize) { ((c * chunk).min(n), ((c + 1) * chunk).min(n)) };
    let next = (rank + 1) % p;
    let prev = (rank + p - 1) % p;

    // Phase 1: reduce-scatter. After p-1 steps, rank r owns the fully
    // reduced chunk (r + 1) % p.
    for s in 0..p - 1 {
        let send_c = (rank + p - s) % p;
        let recv_c = (prev + p - s) % p;
        let (s0, s1) = bounds(send_c);
        let (r0, r1) = bounds(recv_c);
        if rank % 2 == 0 {
            let buf = data[s0..s1].to_vec();
            mesh.send_f64s(next, &buf)?;
            let got = mesh.recv_f64s(prev)?;
            blas1::axpy(1.0, &got, &mut data[r0..r1]);
        } else {
            let got = mesh.recv_f64s(prev)?;
            let buf = data[s0..s1].to_vec();
            mesh.send_f64s(next, &buf)?;
            blas1::axpy(1.0, &got, &mut data[r0..r1]);
        }
    }

    // Phase 2: all-gather the reduced chunks around the ring.
    for s in 0..p - 1 {
        let send_c = (rank + 1 + p - s) % p;
        let recv_c = (rank + p - s) % p;
        let (s0, s1) = bounds(send_c);
        let (r0, r1) = bounds(recv_c);
        if rank % 2 == 0 {
            let buf = data[s0..s1].to_vec();
            mesh.send_f64s(next, &buf)?;
            let got = mesh.recv_f64s(prev)?;
            data[r0..r1].copy_from_slice(&got);
        } else {
            let got = mesh.recv_f64s(prev)?;
            let buf = data[s0..s1].to_vec();
            mesh.send_f64s(next, &buf)?;
            data[r0..r1].copy_from_slice(&got);
        }
    }
    Ok(())
}

/// Expected shape of one inbound [`RingPipeline`] frame.
#[derive(Debug, Clone, Copy)]
pub enum FrameShape {
    /// Exactly `rows x cols` doubles (length-validated in the receiver
    /// thread; a mismatch is a protocol error).
    Matrix(usize, usize),
    /// Any length; delivered as an `n x 1` matrix.
    Any,
}

/// Overlapped ring shift with store-and-forward: a dedicated sender
/// thread and receiver thread per rank over cloned `Mesh` sockets, so
/// panel communication proceeds *while* the owning thread computes — the
/// primitive under the ring-pipelined distributed GEMM (replaces the
/// blocking `exchange`-style pattern, which serialized each shift
/// against the compute between shifts).
///
/// Wire order is fixed at construction: the sender first writes
/// `own_frames` panels enqueued by the compute thread (`send_own`),
/// then forwards the first `forward_frames` inbound frames. Forwarding
/// happens *inside* the pipeline — the receiver hands each decoded frame
/// to the compute thread, then rendezvous-enqueues the same `Arc` to the
/// sender before reading the next frame.
///
/// Memory discipline (this is what bounds the GEMM's B footprint at two
/// whole panels):
/// * the own-panel channel is buffered to `own_frames` entries — a rank
///   in its send-only opening burst must never block on a neighbor
///   (that cycle deadlocks the ring), and all own sub-panels together
///   are at most one whole panel of doubles;
/// * the forward channel and the delivery channel are rendezvous
///   channels: at most one forwarded frame is in flight (sharing its
///   allocation with the compute thread's current panel via `Arc`), and
///   the receiver reads at most one frame ahead — because the forward
///   enqueue only completes once the sender finished the previous
///   frame, the next read cannot start while an earlier allocation is
///   still draining onto the wire.
///
/// Framing matches `Mesh::send_f64s`, so ordinary collectives can follow
/// on the same links once the pipeline is quiesced (`finish`). Dropping
/// without `finish` (error paths) *poisons the links*: both cloned
/// sockets are shut down so the helper threads exit instead of racing a
/// later collective for frames, and subsequent traffic on this mesh
/// fails loudly — matching the driver's mid-collective session-poisoning
/// semantics.
pub struct RingPipeline {
    own_tx: Option<std::sync::mpsc::SyncSender<std::sync::Arc<DenseMatrix>>>,
    /// `Option` so abnormal drop can disconnect the delivery channel
    /// *before* joining the receiver (which may be parked on it).
    recv_rx: Option<std::sync::mpsc::Receiver<Result<std::sync::Arc<DenseMatrix>>>>,
    sender: Option<std::thread::JoinHandle<Result<()>>>,
    receiver: Option<std::thread::JoinHandle<()>>,
    /// Control clones for poisoning on abnormal drop.
    send_ctl: std::net::TcpStream,
    recv_ctl: std::net::TcpStream,
}

impl RingPipeline {
    /// Open a pipeline that sends to ring neighbor `to` and consumes one
    /// frame from neighbor `from` per entry of `frame_shapes` (in that
    /// order). The compute thread must call `send_own` exactly
    /// `own_frames` times and `recv` exactly `frame_shapes.len()` times;
    /// the first `forward_frames` inbound frames are re-sent to `to`
    /// automatically after delivery.
    pub fn new(
        mesh: &mut Mesh,
        to: usize,
        from: usize,
        own_frames: usize,
        forward_frames: usize,
        frame_shapes: Vec<FrameShape>,
    ) -> Result<RingPipeline> {
        if forward_frames > frame_shapes.len() {
            return Err(Error::Protocol(format!(
                "ring pipeline: cannot forward {forward_frames} of {} frames",
                frame_shapes.len()
            )));
        }
        let mut send_sock = mesh.clone_conn(to)?;
        let mut recv_sock = mesh.clone_conn(from)?;
        let send_ctl = send_sock.try_clone()?;
        let recv_ctl = recv_sock.try_clone()?;

        let (own_tx, own_rx) =
            std::sync::mpsc::sync_channel::<std::sync::Arc<DenseMatrix>>(own_frames);
        let (fwd_tx, fwd_rx) =
            std::sync::mpsc::sync_channel::<std::sync::Arc<DenseMatrix>>(0);
        let sender = std::thread::Builder::new()
            .name("ring-send".into())
            .spawn(move || -> Result<()> {
                for _ in 0..own_frames {
                    let Ok(panel) = own_rx.recv() else { return Ok(()) };
                    super::write_f64_frame(&mut send_sock, panel.data())?;
                }
                for _ in 0..forward_frames {
                    let Ok(panel) = fwd_rx.recv() else { return Ok(()) };
                    super::write_f64_frame(&mut send_sock, panel.data())?;
                }
                Ok(())
            })
            .map_err(|e| Error::Protocol(format!("spawn ring sender: {e}")))?;

        let (recv_tx, recv_rx) =
            std::sync::mpsc::sync_channel::<Result<std::sync::Arc<DenseMatrix>>>(0);
        let receiver = std::thread::Builder::new()
            .name("ring-recv".into())
            .spawn(move || {
                for (i, shape) in frame_shapes.into_iter().enumerate() {
                    let decoded = super::recv_f64_frame(&mut recv_sock).and_then(|v| {
                        let (rows, cols) = match shape {
                            FrameShape::Matrix(r, c) => (r, c),
                            FrameShape::Any => (v.len(), 1),
                        };
                        if v.len() != rows * cols {
                            return Err(Error::Protocol(format!(
                                "ring frame {i}: {} doubles, expected {rows}x{cols}",
                                v.len()
                            )));
                        }
                        Ok(std::sync::Arc::new(DenseMatrix::from_vec(rows, cols, v)?))
                    });
                    match decoded {
                        Ok(panel) => {
                            // Hand to the compute thread first (it can
                            // start multiplying), then give the sender
                            // its forward copy; this enqueue gates the
                            // next read on the previous frame draining.
                            if recv_tx.send(Ok(panel.clone())).is_err() {
                                return;
                            }
                            if i < forward_frames && fwd_tx.send(panel).is_err() {
                                return;
                            }
                        }
                        Err(e) => {
                            let _ = recv_tx.send(Err(e));
                            return;
                        }
                    }
                }
            })
            .map_err(|e| Error::Protocol(format!("spawn ring receiver: {e}")))?;

        Ok(RingPipeline {
            own_tx: Some(own_tx),
            recv_rx: Some(recv_rx),
            sender: Some(sender),
            receiver: Some(receiver),
            send_ctl,
            recv_ctl,
        })
    }

    /// Enqueue one of this rank's own panels for sending (buffered up to
    /// `own_frames`, so the opening send-only burst never blocks on ring
    /// neighbors). The caller keeps its `Arc` clone and may compute on
    /// the panel concurrently; panels are immutable once enqueued.
    pub fn send_own(&self, panel: std::sync::Arc<DenseMatrix>) -> Result<()> {
        self.own_tx
            .as_ref()
            .expect("ring pipeline already finished")
            .send(panel)
            .map_err(|_| Error::Protocol("ring sender thread terminated early".into()))
    }

    /// Take the next inbound panel, blocking until it is fully read and
    /// shape-checked. Forwarding (when due) happens automatically.
    pub fn recv(&self) -> Result<std::sync::Arc<DenseMatrix>> {
        let rx = self.recv_rx.as_ref().expect("ring pipeline already finished");
        match rx.recv() {
            Ok(r) => r,
            Err(_) => Err(Error::Protocol("ring receiver thread terminated early".into())),
        }
    }

    /// Quiesce: wait until every frame is on the wire and the receiver
    /// consumed its quota, then reap both threads. The caller must have
    /// consumed every inbound frame (`recv` × `frame_shapes.len()`)
    /// first, or this blocks.
    pub fn finish(mut self) -> Result<()> {
        drop(self.own_tx.take());
        if let Some(h) = self.sender.take() {
            h.join().map_err(|_| Error::Protocol("ring sender panicked".into()))??;
        }
        if let Some(h) = self.receiver.take() {
            h.join().map_err(|_| Error::Protocol("ring receiver panicked".into()))?;
        }
        Ok(())
    }
}

impl Drop for RingPipeline {
    fn drop(&mut self) {
        drop(self.own_tx.take());
        if self.sender.is_none() && self.receiver.is_none() {
            return; // finished cleanly
        }
        // Abnormal teardown (error path): the helper threads may be
        // parked on channel rendezvous or on socket I/O over cloned
        // handles to the session's links. Left alone they would race the
        // next collective for frames, silently corrupting it. Disconnect
        // the channels, shut the links down so every park site errors
        // out and later traffic fails loudly (session poisoning), then
        // reap both threads.
        drop(self.recv_rx.take());
        let _ = self.send_ctl.shutdown(std::net::Shutdown::Both);
        let _ = self.recv_ctl.shutdown(std::net::Shutdown::Both);
        if let Some(h) = self.sender.take() {
            let _ = h.join();
        }
        if let Some(h) = self.receiver.take() {
            let _ = h.join();
        }
    }
}

/// Pipelined sequenced broadcast over an ordered [`SubMesh`] chain — the
/// primitive under both the ascending-k ring GEMM (its p×1 degenerate
/// case) and the 2D SUMMA row/column panel broadcasts.
///
/// The caller supplies a *global frame schedule*: frame `t` originates at
/// sub-rank `schedule[t].0` and every member observes it at position `t`
/// — roots call [`BcastPipeline::send_own`], everyone else
/// [`BcastPipeline::recv`], all in schedule order. Each frame travels the
/// fixed chain root → root+1 → … → root+q−1 (mod q): every member
/// receives from its predecessor and forwards to its successor, except
/// the member whose successor is the frame's root (its last recipient).
/// The wire carries frames in schedule order, so *arrival order equals
/// schedule order at every member* — this is what lets all ranks fold
/// k-panels in globally ascending order, the bitwise-determinism
/// contract of `dist_gemm`.
///
/// Memory discipline (the ≤ 2 in-flight panels per pipeline bound):
/// * the delivery and forward channels are rendezvous, exactly like
///   [`RingPipeline`]: the receiver reads at most one frame ahead, and a
///   forwarded frame shares its allocation with the compute thread's
///   current panel;
/// * own frames are handed over by a two-phase rendezvous: `send_own`
///   first waits for the sender thread to reach the frame's wire slot
///   (the previous frame has fully drained), and only *then*
///   materializes the panel — so an own copy never coexists with both a
///   draining predecessor and the receiver's read-ahead.
///
/// So at any instant at most two schedule-consecutive frames are
/// resident per pipeline. Like `RingPipeline`, dropping without
/// [`BcastPipeline::finish`] poisons both cloned sockets so the helper
/// threads exit and later traffic on the mesh fails loudly.
pub struct BcastPipeline {
    own_tx: Option<std::sync::mpsc::SyncSender<std::sync::Arc<DenseMatrix>>>,
    ready_rx: Option<std::sync::mpsc::Receiver<()>>,
    recv_rx: Option<std::sync::mpsc::Receiver<Result<std::sync::Arc<DenseMatrix>>>>,
    sender: Option<std::thread::JoinHandle<Result<()>>>,
    receiver: Option<std::thread::JoinHandle<()>>,
    send_ctl: std::net::TcpStream,
    recv_ctl: std::net::TcpStream,
}

impl BcastPipeline {
    /// Open the pipeline for one schedule sweep. `schedule[t]` is
    /// `(root sub-rank, expected frame shape)`; the calling rank must
    /// then walk the schedule in order, calling `send_own` on its own
    /// frames and `recv` on every other frame, and `finish` at the end.
    /// Singleton sub-meshes are rejected — broadcasts there are local
    /// no-ops the caller should skip.
    pub fn new(
        mesh: &mut Mesh,
        sub: &super::SubMesh,
        schedule: &[(usize, FrameShape)],
    ) -> Result<BcastPipeline> {
        let q = sub.size();
        if q < 2 {
            return Err(Error::Protocol(
                "bcast pipeline needs >= 2 members (singleton broadcasts are local)".into(),
            ));
        }
        let s = sub.rank();
        let next_sub = (s + 1) % q;
        // Wire plan: `true` = an own frame (rendezvous with the compute
        // thread), `false` = forward an inbound frame. Inbound plan: one
        // (shape, forward?) entry per frame rooted elsewhere.
        let mut wire: Vec<bool> = Vec::new();
        let mut inbound: Vec<(FrameShape, bool)> = Vec::new();
        for &(root, shape) in schedule {
            if root >= q {
                return Err(Error::Protocol(format!(
                    "bcast frame root {root} out of range ({q} members)"
                )));
            }
            if root == s {
                wire.push(true);
            } else {
                let fwd = root != next_sub;
                inbound.push((shape, fwd));
                if fwd {
                    wire.push(false);
                }
            }
        }
        let mut send_sock = mesh.clone_conn(sub.next())?;
        let mut recv_sock = mesh.clone_conn(sub.prev())?;
        let send_ctl = send_sock.try_clone()?;
        let recv_ctl = recv_sock.try_clone()?;

        let (own_tx, own_rx) = std::sync::mpsc::sync_channel::<std::sync::Arc<DenseMatrix>>(0);
        let (ready_tx, ready_rx) = std::sync::mpsc::sync_channel::<()>(0);
        let (fwd_tx, fwd_rx) = std::sync::mpsc::sync_channel::<std::sync::Arc<DenseMatrix>>(0);
        let sender = std::thread::Builder::new()
            .name("bcast-send".into())
            .spawn(move || -> Result<()> {
                for own in wire {
                    let panel = if own {
                        // Two-phase own handoff: signal the slot is open,
                        // then take the panel the compute thread built.
                        if ready_tx.send(()).is_err() {
                            return Ok(());
                        }
                        match own_rx.recv() {
                            Ok(p) => p,
                            Err(_) => return Ok(()),
                        }
                    } else {
                        match fwd_rx.recv() {
                            Ok(p) => p,
                            Err(_) => return Ok(()),
                        }
                    };
                    super::write_f64_frame(&mut send_sock, panel.data())?;
                }
                Ok(())
            })
            .map_err(|e| Error::Protocol(format!("spawn bcast sender: {e}")))?;

        let (recv_tx, recv_rx) =
            std::sync::mpsc::sync_channel::<Result<std::sync::Arc<DenseMatrix>>>(0);
        let receiver = std::thread::Builder::new()
            .name("bcast-recv".into())
            .spawn(move || {
                for (i, (shape, fwd)) in inbound.into_iter().enumerate() {
                    let decoded = super::recv_f64_frame(&mut recv_sock).and_then(|v| {
                        let (rows, cols) = match shape {
                            FrameShape::Matrix(r, c) => (r, c),
                            FrameShape::Any => (v.len(), 1),
                        };
                        if v.len() != rows * cols {
                            return Err(Error::Protocol(format!(
                                "bcast frame {i}: {} doubles, expected {rows}x{cols}",
                                v.len()
                            )));
                        }
                        Ok(std::sync::Arc::new(DenseMatrix::from_vec(rows, cols, v)?))
                    });
                    match decoded {
                        Ok(panel) => {
                            // Deliver first (compute can start), then hand
                            // the sender its forward copy; the rendezvous
                            // gates the next read on this frame draining.
                            if recv_tx.send(Ok(panel.clone())).is_err() {
                                return;
                            }
                            if fwd && fwd_tx.send(panel).is_err() {
                                return;
                            }
                        }
                        Err(e) => {
                            let _ = recv_tx.send(Err(e));
                            return;
                        }
                    }
                }
            })
            .map_err(|e| Error::Protocol(format!("spawn bcast receiver: {e}")))?;

        Ok(BcastPipeline {
            own_tx: Some(own_tx),
            ready_rx: Some(ready_rx),
            recv_rx: Some(recv_rx),
            sender: Some(sender),
            receiver: Some(receiver),
            send_ctl,
            recv_ctl,
        })
    }

    /// Broadcast this rank's next own frame: wait for the sender thread
    /// to reach its wire slot, *then* materialize the panel via `make`
    /// and enqueue it. Returns the panel for local compute (the sender
    /// drains the same allocation concurrently; panels are immutable
    /// once enqueued).
    pub fn send_own(
        &self,
        make: impl FnOnce() -> Result<std::sync::Arc<DenseMatrix>>,
    ) -> Result<std::sync::Arc<DenseMatrix>> {
        let ready = self.ready_rx.as_ref().expect("bcast pipeline already finished");
        ready
            .recv()
            .map_err(|_| Error::Protocol("bcast sender thread terminated early".into()))?;
        let panel = make()?;
        self.own_tx
            .as_ref()
            .expect("bcast pipeline already finished")
            .send(panel.clone())
            .map_err(|_| Error::Protocol("bcast sender thread terminated early".into()))?;
        Ok(panel)
    }

    /// Take the next inbound frame, blocking until it is fully read and
    /// shape-checked. Forwarding (when due) happens automatically.
    pub fn recv(&self) -> Result<std::sync::Arc<DenseMatrix>> {
        let rx = self.recv_rx.as_ref().expect("bcast pipeline already finished");
        match rx.recv() {
            Ok(r) => r,
            Err(_) => Err(Error::Protocol("bcast receiver thread terminated early".into())),
        }
    }

    /// Quiesce after a complete schedule walk and reap both threads.
    pub fn finish(mut self) -> Result<()> {
        drop(self.own_tx.take());
        drop(self.ready_rx.take());
        if let Some(h) = self.sender.take() {
            h.join().map_err(|_| Error::Protocol("bcast sender panicked".into()))??;
        }
        if let Some(h) = self.receiver.take() {
            h.join().map_err(|_| Error::Protocol("bcast receiver panicked".into()))?;
        }
        Ok(())
    }
}

impl Drop for BcastPipeline {
    fn drop(&mut self) {
        drop(self.own_tx.take());
        drop(self.ready_rx.take());
        if self.sender.is_none() && self.receiver.is_none() {
            return; // finished cleanly
        }
        // Abnormal teardown: same session-poisoning semantics as
        // RingPipeline — disconnect channels, shut the cloned links down
        // so parked helper threads error out, then reap them.
        drop(self.recv_rx.take());
        let _ = self.send_ctl.shutdown(std::net::Shutdown::Both);
        let _ = self.recv_ctl.shutdown(std::net::Shutdown::Both);
        if let Some(h) = self.sender.take() {
            let _ = h.join();
        }
        if let Some(h) = self.receiver.take() {
            let _ = h.join();
        }
    }
}

/// Open a [`BcastPipeline`] over `sub` for `schedule` — the `comm`
/// entry point the SUMMA compute plane uses for its row/column panel
/// broadcasts (see the type docs for the protocol).
pub fn bcast_pipelined(
    mesh: &mut Mesh,
    sub: &super::SubMesh,
    schedule: &[(usize, FrameShape)],
) -> Result<BcastPipeline> {
    BcastPipeline::new(mesh, sub, schedule)
}

/// One blocking ring shift without pipelining: send `data` to `to` while
/// receiving one frame from `from` (helper-thread overlap only, no
/// compute overlap). Convenience wrapper over [`RingPipeline`] for
/// single-step callers and tests.
pub fn ring_shift(mesh: &mut Mesh, to: usize, data: &[f64], from: usize) -> Result<Vec<f64>> {
    let pipe = RingPipeline::new(mesh, to, from, 1, 0, vec![FrameShape::Any])?;
    pipe.send_own(std::sync::Arc::new(DenseMatrix::from_vec(data.len(), 1, data.to_vec())?))?;
    let got = pipe.recv()?;
    pipe.finish()?;
    Ok(got.data().to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_mesh;

    #[test]
    fn barrier_completes() {
        // non-power-of-two and power-of-two sizes, plus solo
        for p in [1, 2, 3, 5, 8] {
            run_mesh(p, |mut mesh| barrier(&mut mesh)).unwrap();
        }
    }

    #[test]
    fn barrier_synchronizes() {
        // No rank may exit the barrier before every rank entered it.
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let entered = Arc::new(AtomicUsize::new(0));
        let e = entered.clone();
        run_mesh(6, move |mut mesh| {
            if mesh.rank() == 3 {
                std::thread::sleep(std::time::Duration::from_millis(30));
            }
            e.fetch_add(1, Ordering::SeqCst);
            barrier(&mut mesh)?;
            Ok(e.load(Ordering::SeqCst))
        })
        .unwrap()
        .into_iter()
        .for_each(|seen| assert_eq!(seen, 6, "rank left barrier before all entered"));
    }

    #[test]
    fn allgather_flat_matches_legacy() {
        for p in [1usize, 2, 3, 5] {
            let results = run_mesh(p, move |mut mesh| {
                // ragged: rank r contributes r+1 elements
                let mine: Vec<f64> = (0..mesh.rank() + 1).map(|i| (mesh.rank() * 10 + i) as f64).collect();
                let counts: Vec<usize> = (0..p).map(|r| r + 1).collect();
                allgather_flat(&mut mesh, &mine, &counts)
            })
            .unwrap();
            let mut want = Vec::new();
            for r in 0..p {
                want.extend((0..r + 1).map(|i| (r * 10 + i) as f64));
            }
            for got in results {
                assert_eq!(got, want, "p={p}");
            }
        }
    }

    #[test]
    fn allgather_flat_rejects_bad_counts() {
        let results = run_mesh(2, |mut mesh| {
            let mine = vec![1.0];
            Ok(allgather_flat(&mut mesh, &mine, &[2, 2]).is_err())
        })
        .unwrap();
        assert!(results.iter().all(|&b| b));
    }

    #[test]
    fn ring_shift_rotates() {
        for p in [2usize, 3, 5] {
            let results = run_mesh(p, move |mut mesh| {
                let rank = mesh.rank();
                let to = (rank + p - 1) % p; // send to prev
                let from = (rank + 1) % p; // receive from next
                ring_shift(&mut mesh, to, &[rank as f64; 4], from)
            })
            .unwrap();
            for (r, got) in results.iter().enumerate() {
                assert_eq!(got, &vec![((r + 1) % p) as f64; 4], "p={p}");
            }
        }
    }

    #[test]
    fn ring_pipeline_multi_step_large_frames() {
        // Multiple in-flight shifts with frames far above socket buffers:
        // the dedicated threads must keep both directions draining.
        let p = 3usize;
        let steps = 3usize;
        let n = 200_000usize; // ~1.6 MB frames
        let results = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            let to = (rank + p - 1) % p;
            let from = (rank + 1) % p;
            let pipe =
                RingPipeline::new(&mut mesh, to, from, steps, 0, vec![FrameShape::Any; steps])?;
            let mut cur = std::sync::Arc::new(
                DenseMatrix::from_vec(n, 1, vec![rank as f64; n]).unwrap(),
            );
            for _ in 0..steps {
                pipe.send_own(cur.clone())?;
                cur = pipe.recv()?;
            }
            pipe.finish()?;
            // after `steps` shifts towards prev, we hold the panel of
            // rank (rank + steps) % p
            Ok(cur.data()[0])
        })
        .unwrap();
        for (r, got) in results.iter().enumerate() {
            assert_eq!(*got, ((r + steps) % p) as f64);
        }
    }

    #[test]
    fn ring_pipeline_store_and_forward() {
        // The dist_gemm shape: one own frame per rank, forwarded around
        // the ring by the pipeline itself. Rank r must receive origins
        // r+1 then r+2 (the second via rank r+1's automatic forward).
        // Frames are ~2 MB — above loopback socket buffering, so the
        // forward path runs under real backpressure.
        let p = 3usize;
        let side = 500usize;
        let results = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            let to = (rank + p - 1) % p;
            let from = (rank + 1) % p;
            // 2 inbound frames; forward only the first (the second's
            // origin is `to`, whose last recipient we are)
            let pipe = RingPipeline::new(
                &mut mesh,
                to,
                from,
                1,
                1,
                vec![FrameShape::Matrix(side, side); 2],
            )?;
            let own = std::sync::Arc::new(
                DenseMatrix::from_vec(side, side, vec![rank as f64; side * side]).unwrap(),
            );
            pipe.send_own(own)?;
            let first = pipe.recv()?;
            let second = pipe.recv()?;
            pipe.finish()?;
            Ok((first.data()[0], *first.data().last().unwrap(), second.data()[0]))
        })
        .unwrap();
        for (r, &(first, first_last, second)) in results.iter().enumerate() {
            assert_eq!(first, ((r + 1) % p) as f64);
            assert_eq!(first_last, ((r + 1) % p) as f64);
            assert_eq!(second, ((r + 2) % p) as f64);
        }
    }

    #[test]
    fn sub_mesh_carving_and_validation() {
        run_mesh(4, |mesh| {
            let rank = mesh.rank();
            // grid-row sub-meshes of a 2x2 grid
            let members = if rank < 2 { vec![0usize, 1] } else { vec![2, 3] };
            let sub = crate::comm::SubMesh::new(&mesh, members.clone())?;
            assert_eq!(sub.rank(), rank % 2);
            assert_eq!(sub.size(), 2);
            assert_eq!(sub.members(), &members[..]);
            assert_eq!(sub.global(sub.rank()), rank);
            assert_eq!(sub.next(), members[(rank % 2 + 1) % 2]);
            assert_eq!(sub.prev(), sub.next()); // q = 2: same neighbor
            // not a member / duplicate / out of range all rejected
            let others = if rank < 2 { vec![2usize, 3] } else { vec![0, 1] };
            assert!(crate::comm::SubMesh::new(&mesh, others).is_err());
            assert!(crate::comm::SubMesh::new(&mesh, vec![rank, rank]).is_err());
            assert!(crate::comm::SubMesh::new(&mesh, vec![rank, 9]).is_err());
            assert!(crate::comm::SubMesh::new(&mesh, vec![]).is_err());
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn bcast_pipeline_delivers_in_schedule_order() {
        // Mixed roots over the full mesh as one chain: every rank must
        // observe the frames in schedule order with root-stamped payloads.
        let p = 3usize;
        let results = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            let sub = crate::comm::SubMesh::new(&mesh, (0..p).collect())?;
            let roots = [0usize, 1, 2, 0, 2];
            let schedule: Vec<(usize, FrameShape)> =
                roots.iter().map(|&r| (r, FrameShape::Matrix(2, 2))).collect();
            let pipe = BcastPipeline::new(&mut mesh, &sub, &schedule)?;
            let mut seen = Vec::new();
            for (t, &root) in roots.iter().enumerate() {
                let stamp = (root * 100 + t) as f64;
                let panel = if root == rank {
                    pipe.send_own(|| {
                        Ok(std::sync::Arc::new(
                            DenseMatrix::from_vec(2, 2, vec![stamp; 4]).unwrap(),
                        ))
                    })?
                } else {
                    pipe.recv()?
                };
                seen.push(panel.data()[0]);
                assert_eq!(panel.data()[3], panel.data()[0]);
            }
            pipe.finish()?;
            Ok(seen)
        })
        .unwrap();
        for got in results {
            assert_eq!(got, vec![0.0, 101.0, 202.0, 3.0, 204.0]);
        }
    }

    #[test]
    fn bcast_pipeline_row_and_col_sub_meshes_concurrently() {
        // The SUMMA shape on a 2x2 grid: every rank walks a row pipeline
        // and a column pipeline in lockstep, one frame per step from each.
        let p = 4usize;
        let results = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            let (gr, gc) = (rank / 2, rank % 2);
            let row_members = vec![gr * 2, gr * 2 + 1]; // sub-rank = gc
            let col_members = vec![gc, gc + 2]; // sub-rank = gr
            let row_sub = crate::comm::SubMesh::new(&mesh, row_members)?;
            let col_sub = crate::comm::SubMesh::new(&mesh, col_members)?;
            let steps = 4usize;
            let schedule: Vec<(usize, FrameShape)> =
                (0..steps).map(|t| (t % 2, FrameShape::Matrix(1, 3))).collect();
            let row_pipe = bcast_pipelined(&mut mesh, &row_sub, &schedule)?;
            let col_pipe = bcast_pipelined(&mut mesh, &col_sub, &schedule)?;
            let mut seen = Vec::new();
            for t in 0..steps {
                let row_val = (gr * 10 + t) as f64; // same across a grid row
                let a = if t % 2 == gc {
                    row_pipe.send_own(|| {
                        Ok(std::sync::Arc::new(
                            DenseMatrix::from_vec(1, 3, vec![row_val; 3]).unwrap(),
                        ))
                    })?
                } else {
                    row_pipe.recv()?
                };
                let col_val = (gc * 10 + t) as f64; // same across a grid col
                let b = if t % 2 == gr {
                    col_pipe.send_own(|| {
                        Ok(std::sync::Arc::new(
                            DenseMatrix::from_vec(1, 3, vec![col_val; 3]).unwrap(),
                        ))
                    })?
                } else {
                    col_pipe.recv()?
                };
                assert_eq!(a.data()[0], row_val, "row bcast at step {t}");
                assert_eq!(b.data()[0], col_val, "col bcast at step {t}");
                seen.push((a.data()[0], b.data()[0]));
            }
            row_pipe.finish()?;
            col_pipe.finish()?;
            Ok(seen.len())
        })
        .unwrap();
        assert!(results.iter().all(|&n| n == 4));
    }

    #[test]
    fn bcast_pipeline_forwards_under_backpressure() {
        // Chain of 3 with frames above loopback buffering: middle members
        // must store-and-forward while the compute thread consumes.
        let p = 3usize;
        let side = 400usize; // ~1.3 MB frames
        let results = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            let sub = crate::comm::SubMesh::new(&mesh, (0..p).collect())?;
            let roots = [0usize, 1, 2];
            let schedule: Vec<(usize, FrameShape)> =
                roots.iter().map(|&r| (r, FrameShape::Matrix(side, side))).collect();
            let pipe = BcastPipeline::new(&mut mesh, &sub, &schedule)?;
            let mut sum = 0.0;
            for &root in &roots {
                let panel = if root == rank {
                    pipe.send_own(|| {
                        Ok(std::sync::Arc::new(
                            DenseMatrix::from_vec(side, side, vec![root as f64; side * side])
                                .unwrap(),
                        ))
                    })?
                } else {
                    pipe.recv()?
                };
                assert_eq!(panel.data()[0], root as f64);
                assert_eq!(*panel.data().last().unwrap(), root as f64);
                sum += panel.data()[0];
            }
            pipe.finish()?;
            Ok(sum)
        })
        .unwrap();
        for got in results {
            assert_eq!(got, 3.0); // 0 + 1 + 2 observed everywhere
        }
    }

    #[test]
    fn bcast_pipeline_rejects_bad_schedules() {
        run_mesh(2, |mut mesh| {
            let sub = crate::comm::SubMesh::new(&mesh, vec![0, 1])?;
            // root out of range
            assert!(BcastPipeline::new(&mut mesh, &sub, &[(2, FrameShape::Any)]).is_err());
            // singleton sub-mesh
            let solo = crate::comm::SubMesh::new(&mesh, vec![mesh.rank()])?;
            assert!(BcastPipeline::new(&mut mesh, &solo, &[(0, FrameShape::Any)]).is_err());
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn ring_pipeline_shape_mismatch_is_error() {
        let results = run_mesh(2, |mut mesh| {
            let peer = 1 - mesh.rank();
            let pipe =
                RingPipeline::new(&mut mesh, peer, peer, 1, 0, vec![FrameShape::Matrix(3, 2)])?;
            pipe.send_own(std::sync::Arc::new(
                DenseMatrix::from_vec(2, 2, vec![1.0; 4]).unwrap(),
            ))?;
            // peer sent 4 doubles, we expect 6 -> receiver reports error
            Ok(pipe.recv().is_err())
        })
        .unwrap();
        assert!(results.iter().all(|&e| e));
    }

    #[test]
    fn broadcast_from_each_root() {
        for root in 0..4 {
            let results = run_mesh(4, move |mut mesh| {
                let mut data = if mesh.rank() == root {
                    vec![1.0, 2.0, 3.0, root as f64]
                } else {
                    vec![]
                };
                broadcast(&mut mesh, root, &mut data)?;
                Ok(data)
            })
            .unwrap();
            for r in results {
                assert_eq!(r, vec![1.0, 2.0, 3.0, root as f64]);
            }
        }
    }

    #[test]
    fn gather_collects_in_rank_order() {
        let results = run_mesh(3, |mut mesh| {
            let mine = vec![mesh.rank() as f64; mesh.rank() + 1];
            gather(&mut mesh, 0, &mine)
        })
        .unwrap();
        let root = results[0].as_ref().unwrap();
        assert_eq!(root[0], vec![0.0]);
        assert_eq!(root[1], vec![1.0, 1.0]);
        assert_eq!(root[2], vec![2.0, 2.0, 2.0]);
        assert!(results[1].is_none() && results[2].is_none());
    }

    #[test]
    fn allgather_everyone_gets_everything() {
        let results = run_mesh(4, |mut mesh| {
            let mine = vec![mesh.rank() as f64 * 10.0];
            allgather(&mut mesh, &mine)
        })
        .unwrap();
        for r in &results {
            for (j, part) in r.iter().enumerate() {
                assert_eq!(part, &vec![j as f64 * 10.0]);
            }
        }
    }

    #[test]
    fn allreduce_both_algorithms_match() {
        for algo in [AllReduceAlgo::Naive, AllReduceAlgo::Ring] {
            for p in [1, 2, 3, 4, 7] {
                let results = run_mesh(p, move |mut mesh| {
                    // vector length deliberately not divisible by p
                    let mut data: Vec<f64> =
                        (0..10).map(|i| (mesh.rank() * 100 + i) as f64).collect();
                    allreduce_sum(&mut mesh, &mut data, algo)?;
                    Ok(data)
                })
                .unwrap();
                let want: Vec<f64> = (0..10)
                    .map(|i| (0..p).map(|r| (r * 100 + i) as f64).sum())
                    .collect();
                for r in &results {
                    assert_eq!(r, &want, "algo {algo:?} p {p}");
                }
            }
        }
    }

    #[test]
    fn reduce_sum_only_root_has_result() {
        let results = run_mesh(3, |mut mesh| {
            let data = vec![1.0, 2.0];
            reduce_sum(&mut mesh, 1, &data)
        })
        .unwrap();
        assert!(results[0].is_none());
        assert_eq!(results[1].as_ref().unwrap(), &vec![3.0, 6.0]);
        assert!(results[2].is_none());
    }
}
