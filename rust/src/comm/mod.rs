//! MPI-substitute communicator: point-to-point messaging and collectives
//! over loopback TCP.
//!
//! The paper's server creates "a dedicated MPI communicator for each
//! connected Spark application" (§3.2). [`Mesh`] is that communicator: a
//! fully-connected group of `size` ranks with framed, blocking sockets.
//! Blocking (std::net) on purpose — collectives run inside the worker's
//! compute path (`spawn_blocking`), exactly where MPI calls would sit.
//!
//! Mesh formation follows the usual convention: rank `i` dials every rank
//! `j > i` and accepts connections from every `j < i`; a tiny handshake
//! (`group_id`, `rank`) lets acceptors route sockets when several meshes
//! form concurrently.

pub mod collectives;

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use crate::{Error, Result};

/// Max comm frame: collectives chunk under this.
const MAX_COMM_FRAME: usize = 1 << 30;

/// How long a dialing rank retries while the peer's listener comes up.
const DIAL_TIMEOUT: Duration = Duration::from_secs(20);

/// Per-read deadline on the mesh-formation handshake. The 12 handshake
/// bytes follow the TCP connect immediately, so a peer that connects and
/// then stalls is wedged or hostile — without this bound one bad peer
/// holds session setup (and the session's worker grant) forever.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Overall deadline for the accept side of mesh formation (symmetric
/// with [`DIAL_TIMEOUT`] on the dial side): a lower-rank peer that never
/// dials (it died before entering formation) must error this rank out of
/// `establish` — back to its control loop where the health prober can
/// reach it — rather than wedge it in `accept` forever.
const ACCEPT_TIMEOUT: Duration = Duration::from_secs(20);

/// A fully-connected communicator group.
#[derive(Debug)]
pub struct Mesh {
    rank: usize,
    size: usize,
    /// Connection to each peer rank; `None` at our own index.
    conns: Vec<Option<TcpStream>>,
}

impl Mesh {
    /// Form a mesh. `addrs[j]` is the comm listen address of rank `j`;
    /// `listener` must be the one bound at `addrs[rank]`. Blocks until all
    /// `size - 1` links are up.
    pub fn establish(
        group_id: u64,
        rank: usize,
        addrs: &[String],
        listener: TcpListener,
    ) -> Result<Mesh> {
        let size = addrs.len();
        if rank >= size {
            return Err(Error::Protocol(format!("rank {rank} out of range {size}")));
        }
        let mut conns: Vec<Option<TcpStream>> = (0..size).map(|_| None).collect();

        // Dial higher ranks from a helper thread while we accept lower ones.
        let dial_targets: Vec<(usize, String)> =
            (rank + 1..size).map(|j| (j, addrs[j].clone())).collect();
        let dialer = std::thread::spawn(move || -> Result<Vec<(usize, TcpStream)>> {
            let mut out = Vec::new();
            for (j, addr) in dial_targets {
                let stream = dial_with_retry(&addr)?;
                stream.set_nodelay(true)?;
                let mut s = stream;
                // handshake: group_id, my rank
                s.write_all(&group_id.to_le_bytes())?;
                s.write_all(&(rank as u32).to_le_bytes())?;
                out.push((j, s));
            }
            Ok(out)
        });

        // Accept connections from lower ranks. Handshake reads run under
        // a deadline, and the dialer thread is joined on *every* exit
        // path — an early bad-peer return must not leak a detached thread
        // still writing handshakes into half-formed sockets.
        let accept_result = accept_lower_ranks(group_id, rank, &listener, &mut conns);
        let dial_result = dialer.join().map_err(|_| Error::Protocol("dialer panicked".into()));
        accept_result?;
        for (j, s) in dial_result?? {
            conns[j] = Some(s);
        }
        Ok(Mesh { rank, size, conns })
    }

    /// A size-1 mesh (no sockets) — single-worker sessions.
    pub fn solo() -> Mesh {
        Mesh { rank: 0, size: 1, conns: vec![None] }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    fn conn(&mut self, peer: usize) -> Result<&mut TcpStream> {
        if peer == self.rank || peer >= self.size {
            return Err(Error::Protocol(format!(
                "rank {} cannot talk to peer {peer} (size {})",
                self.rank, self.size
            )));
        }
        self.conns[peer]
            .as_mut()
            .ok_or_else(|| Error::Protocol(format!("no connection to rank {peer}")))
    }

    /// Framed send to one peer.
    pub fn send(&mut self, to: usize, payload: &[u8]) -> Result<()> {
        if payload.len() > MAX_COMM_FRAME {
            return Err(Error::Protocol("comm frame too large".into()));
        }
        let s = self.conn(to)?;
        s.write_all(&(payload.len() as u32).to_le_bytes())?;
        s.write_all(payload)?;
        Ok(())
    }

    /// Framed receive from one peer (blocking).
    pub fn recv(&mut self, from: usize) -> Result<Vec<u8>> {
        let s = self.conn(from)?;
        let mut len = [0u8; 4];
        s.read_exact(&mut len)?;
        let n = u32::from_le_bytes(len) as usize;
        if n > MAX_COMM_FRAME {
            return Err(Error::Protocol(format!("comm frame length {n} exceeds cap")));
        }
        let mut buf = vec![0u8; n];
        s.read_exact(&mut buf)?;
        Ok(buf)
    }

    /// Send a f64 slice (raw LE bytes — the collective hot path). On LE
    /// hosts the payload is written straight from the caller's slice (no
    /// staging copy); the BE fallback converts through a byte buffer.
    pub fn send_f64s(&mut self, to: usize, data: &[f64]) -> Result<()> {
        let s = self.conn(to)?;
        write_f64_frame(s, data)
    }

    /// Clone the socket to `peer` for a helper thread (the overlapped
    /// send/recv pipelines in `collectives` run dedicated threads per
    /// direction over these handles). The caller owns the framing
    /// discipline: while a cloned handle is in use, nothing else may
    /// read (for a recv clone) or write (for a send clone) that link.
    pub(crate) fn clone_conn(&mut self, peer: usize) -> Result<TcpStream> {
        Ok(self.conn(peer)?.try_clone()?)
    }

    /// Deadlock-free simultaneous exchange: send `payload` to `to` while
    /// receiving one frame from `from`. The send runs on a helper thread
    /// over a cloned socket handle, so arbitrarily large frames cannot
    /// jam against full kernel buffers (used by the all-to-all in
    /// `elemental::redistribute`).
    pub fn exchange(&mut self, to: usize, payload: &[u8], from: usize) -> Result<Vec<u8>> {
        if to == from {
            // pure pairwise swap
            let send_sock = self.conn(to)?.try_clone()?;
            let data = payload.to_vec();
            let writer = std::thread::spawn(move || -> Result<()> {
                let mut s = send_sock;
                s.write_all(&(data.len() as u32).to_le_bytes())?;
                s.write_all(&data)?;
                Ok(())
            });
            let got = self.recv(from)?;
            writer.join().map_err(|_| Error::Protocol("exchange writer panicked".into()))??;
            return Ok(got);
        }
        let send_sock = self.conn(to)?.try_clone()?;
        let data = payload.to_vec();
        let writer = std::thread::spawn(move || -> Result<()> {
            let mut s = send_sock;
            s.write_all(&(data.len() as u32).to_le_bytes())?;
            s.write_all(&data)?;
            Ok(())
        });
        let got = self.recv(from)?;
        writer.join().map_err(|_| Error::Protocol("exchange writer panicked".into()))??;
        Ok(got)
    }

    pub fn recv_f64s(&mut self, from: usize) -> Result<Vec<f64>> {
        let s = self.conn(from)?;
        recv_f64_frame(s)
    }

    /// Receive one f64 frame into a caller-provided slice whose length
    /// must match the frame exactly (flat collectives receive straight
    /// into their pre-sized output, no intermediate Vec).
    pub fn recv_f64s_into(&mut self, from: usize, out: &mut [f64]) -> Result<()> {
        let s = self.conn(from)?;
        let mut len = [0u8; 4];
        s.read_exact(&mut len)?;
        let n = u32::from_le_bytes(len) as usize;
        if n != out.len() * 8 {
            return Err(Error::Protocol(format!(
                "f64 frame is {n} bytes, expected {}",
                out.len() * 8
            )));
        }
        read_f64s_exact(s, out)
    }
}

/// A sub-communicator carved out of an established [`Mesh`]: an ordered
/// subset of its ranks (a grid row or column, for the 2D SUMMA path)
/// addressed by *sub-rank*. No new sockets are opened — operations borrow
/// the parent mesh's links (the pipelined broadcast clones them exactly
/// like [`collectives::RingPipeline`] does), so carving is free and two
/// sub-meshes over disjoint neighbor pairs can run pipelines concurrently.
#[derive(Debug, Clone)]
pub struct SubMesh {
    /// Global mesh ranks of the members, in sub-rank order.
    members: Vec<usize>,
    /// This rank's index in `members`.
    rank: usize,
}

impl SubMesh {
    /// Carve a sub-mesh containing `members` (global mesh ranks, in the
    /// order that defines sub-ranks). The calling rank must be a member,
    /// and members must be distinct in-range ranks.
    pub fn new(mesh: &Mesh, members: Vec<usize>) -> Result<SubMesh> {
        if members.is_empty() {
            return Err(Error::Protocol("sub-mesh needs >= 1 member".into()));
        }
        let mut seen = vec![false; mesh.size()];
        for &m in &members {
            if m >= mesh.size() {
                return Err(Error::Protocol(format!(
                    "sub-mesh member {m} out of range (mesh size {})",
                    mesh.size()
                )));
            }
            if seen[m] {
                return Err(Error::Protocol(format!("sub-mesh member {m} listed twice")));
            }
            seen[m] = true;
        }
        let Some(rank) = members.iter().position(|&m| m == mesh.rank()) else {
            return Err(Error::Protocol(format!(
                "rank {} is not a member of the sub-mesh {members:?}",
                mesh.rank()
            )));
        };
        Ok(SubMesh { members, rank })
    }

    /// This rank's sub-rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.members.len()
    }

    pub fn members(&self) -> &[usize] {
        &self.members
    }

    /// Global rank of sub-rank `s`.
    pub fn global(&self, s: usize) -> usize {
        self.members[s]
    }

    /// Global rank of this rank's successor on the sub-mesh ring.
    pub fn next(&self) -> usize {
        self.members[(self.rank + 1) % self.members.len()]
    }

    /// Global rank of this rank's predecessor on the sub-mesh ring.
    pub fn prev(&self) -> usize {
        self.members[(self.rank + self.members.len() - 1) % self.members.len()]
    }
}

/// View a f64 slice as raw bytes (LE hosts only; f64 has no padding and
/// u8 alignment is never stricter).
#[cfg(target_endian = "little")]
pub(crate) fn f64s_as_bytes(v: &[f64]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), std::mem::size_of_val(v)) }
}

/// Write one length-prefixed f64 frame (the comm wire format used by all
/// point-to-point f64 traffic, including the overlapped ring pipelines'
/// dedicated sender threads).
pub(crate) fn write_f64_frame(w: &mut impl Write, data: &[f64]) -> Result<()> {
    let byte_len = data.len() * 8;
    if byte_len > MAX_COMM_FRAME {
        return Err(Error::Protocol("comm frame too large".into()));
    }
    w.write_all(&(byte_len as u32).to_le_bytes())?;
    #[cfg(target_endian = "little")]
    {
        w.write_all(f64s_as_bytes(data))?;
    }
    #[cfg(not(target_endian = "little"))]
    {
        let mut bytes = Vec::with_capacity(byte_len);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&bytes)?;
    }
    Ok(())
}

/// Read exactly `out.len()` f64s from `r` (single `read_exact` into the
/// slice's byte view on LE hosts).
pub(crate) fn read_f64s_exact(r: &mut impl Read, out: &mut [f64]) -> Result<()> {
    #[cfg(target_endian = "little")]
    {
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(out.as_mut_ptr().cast::<u8>(), std::mem::size_of_val(out))
        };
        r.read_exact(bytes)?;
    }
    #[cfg(not(target_endian = "little"))]
    {
        let mut buf = vec![0u8; out.len() * 8];
        r.read_exact(&mut buf)?;
        for (dst, c) in out.iter_mut().zip(buf.chunks_exact(8)) {
            *dst = f64::from_le_bytes(c.try_into().unwrap());
        }
    }
    Ok(())
}

/// Read one length-prefixed f64 frame from `r` into a fresh Vec.
pub(crate) fn recv_f64_frame(r: &mut impl Read) -> Result<Vec<f64>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_COMM_FRAME {
        return Err(Error::Protocol(format!("comm frame length {n} exceeds cap")));
    }
    if n % 8 != 0 {
        return Err(Error::Protocol("f64 frame not multiple of 8".into()));
    }
    let mut out = vec![0.0f64; n / 8];
    read_f64s_exact(r, &mut out)?;
    Ok(out)
}

/// Accept one mesh-formation connection under a deadline. The listener
/// flips to non-blocking and is polled until `deadline`; the accepted
/// stream is returned to blocking mode (collectives rely on it).
fn accept_with_deadline(listener: &TcpListener, deadline: Instant) -> Result<TcpStream> {
    listener.set_nonblocking(true)?;
    let res = loop {
        match listener.accept() {
            Ok((s, _)) => break Ok(s),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    break Err(Error::Protocol(
                        "mesh formation: timed out waiting for a peer to dial".into(),
                    ));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => break Err(Error::Io(e)),
        }
    };
    let _ = listener.set_nonblocking(false);
    let s = res?;
    s.set_nonblocking(false)?;
    Ok(s)
}

/// Accept-side half of mesh formation: take `rank` connections off the
/// listener (all within [`ACCEPT_TIMEOUT`]), handshake each under
/// [`HANDSHAKE_TIMEOUT`], and slot them by dialer rank. Streams are
/// returned to blocking mode before storage (collectives rely on
/// blocking reads).
fn accept_lower_ranks(
    group_id: u64,
    rank: usize,
    listener: &TcpListener,
    conns: &mut [Option<TcpStream>],
) -> Result<()> {
    let deadline = Instant::now() + ACCEPT_TIMEOUT;
    let mut accepted = 0;
    while accepted < rank {
        let mut s = accept_with_deadline(listener, deadline)?;
        s.set_nodelay(true)?;
        s.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
        let mut gid = [0u8; 8];
        s.read_exact(&mut gid)?;
        let got_gid = u64::from_le_bytes(gid);
        let mut rk = [0u8; 4];
        s.read_exact(&mut rk)?;
        let from = u32::from_le_bytes(rk) as usize;
        if got_gid != group_id {
            return Err(Error::Protocol(format!(
                "mesh handshake: expected group {group_id}, got {got_gid}"
            )));
        }
        if from >= rank || conns[from].is_some() {
            return Err(Error::Protocol(format!("mesh handshake: bad dialer rank {from}")));
        }
        s.set_read_timeout(None)?;
        conns[from] = Some(s);
        accepted += 1;
    }
    Ok(())
}

fn dial_with_retry(addr: &str) -> Result<TcpStream> {
    let deadline = Instant::now() + DIAL_TIMEOUT;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() > deadline {
                    return Err(Error::Io(e));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// Test/bench helper: spin up a full mesh in-process, one thread per rank,
/// run `f(mesh)` on each, and return the per-rank outputs in rank order.
pub fn run_mesh<T: Send + 'static>(
    size: usize,
    f: impl Fn(Mesh) -> Result<T> + Send + Sync + 'static,
) -> Result<Vec<T>> {
    let listeners: Vec<TcpListener> =
        (0..size).map(|_| TcpListener::bind("127.0.0.1:0")).collect::<std::io::Result<_>>()?;
    let addrs: Vec<String> =
        listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
    let f = std::sync::Arc::new(f);
    let mut handles = Vec::new();
    for (rank, listener) in listeners.into_iter().enumerate() {
        let addrs = addrs.clone();
        let f = f.clone();
        handles.push(std::thread::spawn(move || -> Result<T> {
            let mesh = Mesh::establish(0xC0FFEE, rank, &addrs, listener)?;
            f(mesh)
        }));
    }
    let mut out = Vec::with_capacity(size);
    for h in handles {
        out.push(h.join().map_err(|_| Error::Protocol("mesh thread panicked".into()))??);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_forms_and_p2p_works() {
        let results = run_mesh(4, |mut mesh| {
            let rank = mesh.rank();
            // ring: send my rank to (rank+1) % size, receive from prev.
            // ordered to avoid deadlock: evens send first.
            let next = (rank + 1) % mesh.size();
            let prev = (rank + mesh.size() - 1) % mesh.size();
            let payload = vec![rank as u8];
            if rank % 2 == 0 {
                mesh.send(next, &payload)?;
                Ok(mesh.recv(prev)?[0] as usize)
            } else {
                let got = mesh.recv(prev)?[0] as usize;
                mesh.send(next, &payload)?;
                Ok(got)
            }
        })
        .unwrap();
        assert_eq!(results, vec![3, 0, 1, 2]);
    }

    #[test]
    fn f64_payloads_roundtrip() {
        let results = run_mesh(2, |mut mesh| {
            if mesh.rank() == 0 {
                mesh.send_f64s(1, &[1.5, -2.5, 1e300])?;
                Ok(vec![])
            } else {
                mesh.recv_f64s(0)
            }
        })
        .unwrap();
        assert_eq!(results[1], vec![1.5, -2.5, 1e300]);
    }

    #[test]
    fn recv_f64s_into_checks_length() {
        let results = run_mesh(2, |mut mesh| {
            if mesh.rank() == 0 {
                mesh.send_f64s(1, &[1.0, 2.0, 3.0])?;
                mesh.send_f64s(1, &[4.0])?;
                Ok(vec![])
            } else {
                let mut buf = [0.0f64; 3];
                mesh.recv_f64s_into(0, &mut buf)?;
                // wrong-size target is a protocol error (frame has 1 f64)
                let mut wrong = [0.0f64; 2];
                assert!(mesh.recv_f64s_into(0, &mut wrong).is_err());
                Ok(buf.to_vec())
            }
        })
        .unwrap();
        assert_eq!(results[1], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn establish_errors_on_wedged_handshake_peer() {
        // Rank 1 of a size-2 mesh accepts one connection from rank 0. A
        // peer that connects but never sends its handshake must produce
        // an error within the handshake deadline — not hang session
        // setup forever while the worker grant is held.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let addrs = vec!["127.0.0.1:1".to_string(), addr.clone()];
        let _wedged = TcpStream::connect(&addr).unwrap();
        let t = Instant::now();
        assert!(Mesh::establish(7, 1, &addrs, listener).is_err());
        assert!(
            t.elapsed() < Duration::from_secs(15),
            "handshake read not bounded: {:?}",
            t.elapsed()
        );
    }

    #[test]
    fn solo_mesh_has_no_peers() {
        let mut m = Mesh::solo();
        assert_eq!(m.size(), 1);
        assert!(m.send(0, b"x").is_err());
        assert!(m.send(1, b"x").is_err());
    }
}
