//! Parameter-list helpers — the ALI `Parameters` header analogue: typed
//! access to the serialized (name, value) lists that cross the driver
//! control plane.

use crate::protocol::{ParamValue, Params};
use crate::{Error, Result};

/// Look up a parameter by name.
pub fn get<'a>(params: &'a Params, name: &str) -> Result<&'a ParamValue> {
    params
        .iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v)
        .ok_or_else(|| Error::Ali(format!("missing parameter {name:?}")))
}

pub fn get_i64(params: &Params, name: &str) -> Result<i64> {
    get(params, name)?.as_i64()
}

pub fn get_f64(params: &Params, name: &str) -> Result<f64> {
    get(params, name)?.as_f64()
}

pub fn get_matrix(params: &Params, name: &str) -> Result<u64> {
    get(params, name)?.as_matrix()
}

pub fn get_str<'a>(params: &'a Params, name: &str) -> Result<&'a str> {
    get(params, name)?.as_str()
}

/// Optional string parameter: `Ok(None)` when absent, type error when
/// present but not a string.
pub fn get_str_opt<'a>(params: &'a Params, name: &str) -> Result<Option<&'a str>> {
    match params.iter().find(|(k, _)| k == name) {
        Some((_, v)) => Ok(Some(v.as_str()?)),
        None => Ok(None),
    }
}

pub fn get_i64_or(params: &Params, name: &str, default: i64) -> Result<i64> {
    match params.iter().find(|(k, _)| k == name) {
        Some((_, v)) => v.as_i64(),
        None => Ok(default),
    }
}

pub fn get_f64_or(params: &Params, name: &str, default: f64) -> Result<f64> {
    match params.iter().find(|(k, _)| k == name) {
        Some((_, v)) => v.as_f64(),
        None => Ok(default),
    }
}

/// Fluent builder for call-site ergonomics (client + tests).
#[derive(Debug, Default, Clone)]
pub struct ParamsBuilder {
    params: Params,
}

impl ParamsBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn matrix(mut self, name: &str, handle: u64) -> Self {
        self.params.push((name.to_string(), ParamValue::Matrix(handle)));
        self
    }

    pub fn i64(mut self, name: &str, v: i64) -> Self {
        self.params.push((name.to_string(), ParamValue::I64(v)));
        self
    }

    pub fn f64(mut self, name: &str, v: f64) -> Self {
        self.params.push((name.to_string(), ParamValue::F64(v)));
        self
    }

    pub fn str(mut self, name: &str, v: &str) -> Self {
        self.params.push((name.to_string(), ParamValue::Str(v.to_string())));
        self
    }

    pub fn build(self) -> Params {
        self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_and_accessors() {
        let p = ParamsBuilder::new()
            .matrix("A", 7)
            .i64("k", 20)
            .f64("tol", 1e-8)
            .str("mode", "tall")
            .build();
        assert_eq!(get_matrix(&p, "A").unwrap(), 7);
        assert_eq!(get_i64(&p, "k").unwrap(), 20);
        assert_eq!(get_f64(&p, "tol").unwrap(), 1e-8);
        assert_eq!(get_str(&p, "mode").unwrap(), "tall");
        assert!(get(&p, "missing").is_err());
        assert_eq!(get_i64_or(&p, "missing", 5).unwrap(), 5);
        assert_eq!(get_f64_or(&p, "tol", 0.0).unwrap(), 1e-8);
        assert_eq!(get_str_opt(&p, "mode").unwrap(), Some("tall"));
        assert_eq!(get_str_opt(&p, "missing").unwrap(), None);
        assert!(get_str_opt(&p, "k").is_err()); // present, wrong type
    }

    #[test]
    fn type_mismatch_is_ali_error() {
        let p = ParamsBuilder::new().str("x", "hi").build();
        assert!(get_matrix(&p, "x").is_err());
        assert!(get_i64(&p, "x").is_err());
    }
}
