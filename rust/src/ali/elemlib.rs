//! `ElemLib` — the bundled MPI-library substitute, playing the role of
//! "Elemental + ARPACK wrapped by an ALI" in the paper's experiments.
//!
//! Since the typed routine engine the library is a thin shell over a
//! [`RoutineRegistry`]: each routine lives in its own module under
//! [`crate::ali::routines`] with a typed [`RoutineSpec`] (param schema,
//! shape rules, cost estimate). `run` validates the params frame against
//! the spec on every rank — identically, so a rejection is
//! SPMD-deterministic and happens before any collective — then dispatches
//! to the routine body.
//!
//! Routines (see `cargo run --example describe_routines` for the full
//! table): `gemm`, `truncated_svd`, `condest`, `fro_norm`, `scale`,
//! `redistribute`, `transpose`, `add`, `gramian`, `col_stats`, `lstsq`.

use crate::ali::registry::RoutineRegistry;
use crate::ali::{routines, Library, RoutineCtx, RoutineOutput};
use crate::protocol::Params;
use crate::{Error, Result};

/// The builtin library instance.
pub struct ElemLib {
    registry: RoutineRegistry,
}

impl Default for ElemLib {
    fn default() -> Self {
        ElemLib::new()
    }
}

impl std::fmt::Debug for ElemLib {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ElemLib").field("routines", &self.registry.names()).finish()
    }
}

impl ElemLib {
    pub fn new() -> ElemLib {
        ElemLib { registry: routines::registry() }
    }
}

impl Library for ElemLib {
    fn name(&self) -> &str {
        "elemlib"
    }

    fn routines(&self) -> Vec<&'static str> {
        self.registry.names()
    }

    fn registry(&self) -> Option<&RoutineRegistry> {
        Some(&self.registry)
    }

    fn run(
        &self,
        routine: &str,
        params: &Params,
        ctx: &mut RoutineCtx<'_>,
    ) -> Result<RoutineOutput> {
        let r = self.registry.get(routine).ok_or_else(|| {
            Error::Ali(format!(
                "elemlib has no routine {routine:?} (available: {:?})",
                self.routines()
            ))
        })?;
        // Worker-side validation mirrors the driver's pre-admission pass:
        // same spec, same params frame, metadata identical on every rank.
        r.spec().validate(params, |h| ctx.store.get(h).ok().map(|p| p.meta.clone()))?;
        r.run(params, ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ali::params::ParamsBuilder;
    use crate::ali::task::{CancelToken, ProgressSink};
    use crate::comm::run_mesh;
    use crate::elemental::dist_gemm::NativeBackend;
    use crate::elemental::panel::{gather_matrix, scatter_matrix};
    use crate::elemental::{LocalPanel, MatrixStore};
    use crate::linalg::DenseMatrix;
    use crate::protocol::{LayoutDesc, LayoutKind, MatrixMeta, PROTOCOL_VERSION};
    use crate::workload::random_matrix;
    use std::sync::Arc;

    /// Drive an elemlib routine SPMD over an in-process mesh with each
    /// rank's store pre-seeded by `seed_panels`.
    fn run_routine(
        p: usize,
        seed_panels: Vec<Vec<LocalPanel>>, // [rank][panels]
        routine: &'static str,
        params: Params,
        output_handles: Vec<u64>,
    ) -> Vec<(RoutineOutput, MatrixStore)> {
        let seed = Arc::new(seed_panels);
        let params = Arc::new(params);
        let handles = Arc::new(output_handles);
        run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            let mut store = MatrixStore::new();
            for panel in &seed[rank] {
                store.insert(panel.clone()).unwrap();
            }
            let lib = ElemLib::new();
            let mut ctx = RoutineCtx {
                mesh: &mut mesh,
                owners: (0..p as u32).collect(),
                store: &mut store,
                output_handles: &handles,
                backend: &NativeBackend,
                runtime: None,
                svd_pjrt: false,
                compute: Default::default(),
                cancel: CancelToken::new(),
                progress: ProgressSink::disabled(),
                wire_version: PROTOCOL_VERSION,
            };
            let out = lib.run(routine, &params, &mut ctx)?;
            Ok((out, store))
        })
        .unwrap()
    }

    fn meta(handle: u64, rows: u64, cols: u64, p: u32) -> MatrixMeta {
        MatrixMeta {
            handle,
            rows,
            cols,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: (0..p).collect() },
        }
    }

    fn seed(
        handle: u64,
        rows: usize,
        cols: usize,
        p: usize,
        s: u64,
    ) -> (DenseMatrix, Vec<Vec<LocalPanel>>) {
        let full = DenseMatrix::from_vec(rows, cols, random_matrix(s, rows, cols)).unwrap();
        let panels =
            scatter_matrix(&meta(handle, rows as u64, cols as u64, p as u32), &full).unwrap();
        (full, panels.into_iter().map(|x| vec![x]).collect())
    }

    #[test]
    fn gemm_routine_end_to_end() {
        let p = 3;
        let (a_full, mut a_panels) = seed(1, 31, 7, p, 1);
        let (b_full, b_panels) = seed(2, 7, 5, p, 2);
        for (ap, bp) in a_panels.iter_mut().zip(b_panels) {
            ap.extend(bp);
        }
        let params = ParamsBuilder::new().matrix("A", 1).matrix("B", 2).build();
        let results = run_routine(p, a_panels, "gemm", params, vec![100]);
        let c_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(100).unwrap().clone()).collect();
        let c = gather_matrix(&c_panels).unwrap();
        let want = crate::linalg::gemm::gemm(&a_full, &b_full).unwrap();
        assert!(c.max_abs_diff(&want).unwrap() < 1e-10);
        assert_eq!(results[0].0.new_matrices.len(), 1);
        assert_eq!(results[0].0.new_matrices[0].handle, 100);
    }

    #[test]
    fn gemm_routine_algo_params() {
        // "ring" and "allgather" via routine params are bit-identical;
        // a bogus algo is rejected by the spec before any collective.
        let p = 3;
        let (_, mut a_panels) = seed(1, 19, 7, p, 31);
        let (_, b_panels) = seed(2, 7, 5, p, 32);
        for (ap, bp) in a_panels.iter_mut().zip(b_panels) {
            ap.extend(bp);
        }
        let mut gathered = Vec::new();
        for algo in ["ring", "allgather"] {
            let params = ParamsBuilder::new()
                .matrix("A", 1)
                .matrix("B", 2)
                .str("algo", algo)
                .i64("panel_rows", 2)
                .build();
            let results = run_routine(p, a_panels.clone(), "gemm", params, vec![100]);
            let c_panels: Vec<LocalPanel> =
                results.iter().map(|(_, s)| s.get(100).unwrap().clone()).collect();
            gathered.push(gather_matrix(&c_panels).unwrap());
        }
        assert_eq!(gathered[0], gathered[1], "ring vs allgather through the routine layer");

        let params = ParamsBuilder::new()
            .matrix("A", 1)
            .matrix("B", 2)
            .str("algo", "summa3d")
            .build();
        let results = run_routine_fallible(p, a_panels, "gemm", params, vec![100]);
        assert!(results.iter().all(|r| r.is_err()));
    }

    /// Like `run_routine` but returning each rank's `Result` (for tests
    /// exercising SPMD error paths).
    fn run_routine_fallible(
        p: usize,
        seed_panels: Vec<Vec<LocalPanel>>,
        routine: &'static str,
        params: Params,
        output_handles: Vec<u64>,
    ) -> Vec<std::result::Result<RoutineOutput, String>> {
        let seed = Arc::new(seed_panels);
        let params = Arc::new(params);
        let handles = Arc::new(output_handles);
        run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            let mut store = MatrixStore::new();
            for panel in &seed[rank] {
                store.insert(panel.clone()).unwrap();
            }
            let lib = ElemLib::new();
            let mut ctx = RoutineCtx {
                mesh: &mut mesh,
                owners: (0..p as u32).collect(),
                store: &mut store,
                output_handles: &handles,
                backend: &NativeBackend,
                runtime: None,
                svd_pjrt: false,
                compute: Default::default(),
                cancel: CancelToken::new(),
                progress: ProgressSink::disabled(),
                wire_version: PROTOCOL_VERSION,
            };
            Ok(lib.run(routine, &params, &mut ctx).map_err(|e| e.to_string()))
        })
        .unwrap()
    }

    #[test]
    fn truncated_svd_routine_matches_local_reference() {
        let p = 2;
        let (a_full, a_panels) = seed(1, 60, 16, p, 3);
        let params = ParamsBuilder::new().matrix("A", 1).i64("k", 4).build();
        let results = run_routine(p, a_panels, "truncated_svd", params, vec![10, 11, 12]);

        // reference via local ARPACK-substitute
        let want = crate::arpack::truncated_svd_local(
            &a_full,
            4,
            &crate::arpack::LanczosOptions::default(),
        )
        .unwrap();

        // singular values from the distributed S (Replicated since v6:
        // every rank stores the full k x 1 vector)
        let s_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(11).unwrap().clone()).collect();
        assert_eq!(s_panels[0].meta.layout.kind, LayoutKind::Replicated);
        assert_eq!(s_panels[0].local_rows(), 4, "replicated panel holds all rows");
        let s = gather_matrix(&s_panels).unwrap();
        for i in 0..4 {
            assert!(
                (s.get(i, 0) - want.singular_values[i]).abs() < 1e-6,
                "sigma_{i}: {} vs {}",
                s.get(i, 0),
                want.singular_values[i]
            );
        }

        // U, V reproduce A V = U Σ
        let u_panels: Vec<LocalPanel> =
            results.iter().map(|(_, st)| st.get(10).unwrap().clone()).collect();
        let v_panels: Vec<LocalPanel> =
            results.iter().map(|(_, st)| st.get(12).unwrap().clone()).collect();
        let u = gather_matrix(&u_panels).unwrap();
        let v = gather_matrix(&v_panels).unwrap();
        let av = crate::linalg::gemm::gemm(&a_full, &v).unwrap();
        for j in 0..4 {
            for i in 0..60 {
                let lhs = av.get(i, j);
                let rhs = s.get(j, 0) * u.get(i, j);
                assert!((lhs - rhs).abs() < 1e-6, "AV=UΣ at ({i},{j}): {lhs} vs {rhs}");
            }
        }
        // scalar outputs present on rank 0
        assert!(results[0].0.outputs.iter().any(|(k, _)| k == "matvecs"));
    }

    #[test]
    fn truncated_svd_v5_sessions_keep_rowblock_small_outputs() {
        // Pre-v6 clients cannot decode the Replicated layout tag: the
        // routine must fall back to RowBlock slicing (the k < p edge then
        // legitimately leaves owners with zero rows).
        let p = 3;
        let (_, a_panels) = seed(1, 30, 8, p, 13);
        let k = 2usize; // k < p: some RowBlock owners of S hold no rows
        let seed_panels = Arc::new(a_panels);
        let params =
            Arc::new(ParamsBuilder::new().matrix("A", 1).i64("k", k as i64).build());
        let results = run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            let mut store = MatrixStore::new();
            for panel in &seed_panels[rank] {
                store.insert(panel.clone()).unwrap();
            }
            let lib = ElemLib::new();
            let mut ctx = RoutineCtx {
                mesh: &mut mesh,
                owners: (0..p as u32).collect(),
                store: &mut store,
                output_handles: &[20, 21, 22],
                backend: &NativeBackend,
                runtime: None,
                svd_pjrt: false,
                compute: Default::default(),
                cancel: CancelToken::new(),
                progress: ProgressSink::disabled(),
                wire_version: 5,
            };
            lib.run("truncated_svd", &params, &mut ctx)?;
            Ok(store)
        })
        .unwrap();
        let s_panels: Vec<LocalPanel> =
            results.iter().map(|st| st.get(21).unwrap().clone()).collect();
        assert_eq!(s_panels[0].meta.layout.kind, LayoutKind::RowBlock);
        // k=2 rows over 3 owners: block = 1, so the last owner is empty.
        assert_eq!(s_panels[2].local_rows(), 0, "zero-row owner in the k < p edge");
        let s = gather_matrix(&s_panels).unwrap();
        assert_eq!(s.rows(), k);
    }

    #[test]
    fn fro_norm_and_scale() {
        let p = 2;
        let (a_full, a_panels) = seed(1, 12, 3, p, 4);
        let params = ParamsBuilder::new().matrix("A", 1).build();
        let results = run_routine(p, a_panels.clone(), "fro_norm", params, vec![]);
        let (out, _) = &results[0];
        let got = out.outputs[0].1.as_f64().unwrap();
        assert!((got - a_full.frobenius_norm()).abs() < 1e-10);

        let params = ParamsBuilder::new().matrix("A", 1).f64("alpha", -2.0).build();
        let results = run_routine(p, a_panels, "scale", params, vec![50]);
        let b_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(50).unwrap().clone()).collect();
        let b = gather_matrix(&b_panels).unwrap();
        assert!((b.get(3, 1) + 2.0 * a_full.get(3, 1)).abs() < 1e-12);
    }

    #[test]
    fn redistribute_routine() {
        let p = 3;
        let (a_full, a_panels) = seed(1, 17, 2, p, 5);
        let params = ParamsBuilder::new().matrix("A", 1).str("kind", "row_cyclic").build();
        let results = run_routine(p, a_panels, "redistribute", params, vec![60]);
        let b_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(60).unwrap().clone()).collect();
        assert_eq!(b_panels[0].meta.layout.kind, LayoutKind::RowCyclic);
        let b = gather_matrix(&b_panels).unwrap();
        assert_eq!(b, a_full);
    }

    #[test]
    fn condest_identity_is_one() {
        let p = 2;
        let n = 12;
        let full = DenseMatrix::identity(n);
        let panels = scatter_matrix(&meta(1, n as u64, n as u64, p as u32), &full).unwrap();
        let params = ParamsBuilder::new().matrix("A", 1).i64("probes", 6).build();
        let results = run_routine(
            p,
            panels.into_iter().map(|x| vec![x]).collect(),
            "condest",
            params,
            vec![],
        );
        let got = results[0].0.outputs[0].1.as_f64().unwrap();
        assert!((got - 1.0).abs() < 1e-6, "condest {got}");
    }

    #[test]
    fn transpose_routine_matches_local() {
        let p = 3;
        let (a_full, a_panels) = seed(1, 14, 9, p, 21);
        let params = ParamsBuilder::new().matrix("A", 1).build();
        let results = run_routine(p, a_panels, "transpose", params, vec![70]);
        // cell-wise assembled panels: reassemble from local storage
        let mut bt = DenseMatrix::zeros(9, 14);
        for (_, st) in &results {
            let panel = st.get(70).unwrap();
            let layout = panel.layout();
            for li in 0..panel.local_rows() {
                let gr = layout.global_index(panel.slot, li as u64) as usize;
                bt.row_mut(gr).copy_from_slice(panel.local().row(li));
            }
        }
        assert_eq!(bt, a_full.transpose());
    }

    #[test]
    fn add_routine_linear_combination() {
        let p = 2;
        let (a_full, mut a_panels) = seed(1, 10, 4, p, 22);
        let (b_full, b_panels) = seed(2, 10, 4, p, 23);
        for (ap, bp) in a_panels.iter_mut().zip(b_panels) {
            ap.extend(bp);
        }
        let params = ParamsBuilder::new()
            .matrix("A", 1)
            .matrix("B", 2)
            .f64("alpha", 2.0)
            .f64("beta", -0.5)
            .build();
        let results = run_routine(p, a_panels, "add", params, vec![71]);
        let c_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(71).unwrap().clone()).collect();
        let c = gather_matrix(&c_panels).unwrap();
        for i in 0..10 {
            for j in 0..4 {
                let want = 2.0 * a_full.get(i, j) - 0.5 * b_full.get(i, j);
                assert!((c.get(i, j) - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gramian_routine_matches_local() {
        let p = 2;
        let (a_full, a_panels) = seed(1, 30, 6, p, 24);
        let params = ParamsBuilder::new().matrix("A", 1).build();
        let results = run_routine(p, a_panels, "gramian", params, vec![72]);
        let g_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(72).unwrap().clone()).collect();
        let g = gather_matrix(&g_panels).unwrap();
        let want = crate::linalg::gemm::gemm_tn(&a_full, &a_full).unwrap();
        assert!(g.max_abs_diff(&want).unwrap() < 1e-9);
    }

    #[test]
    fn col_stats_routine() {
        let p = 2;
        let (a_full, a_panels) = seed(1, 40, 3, p, 25);
        let params = ParamsBuilder::new().matrix("A", 1).build();
        let results = run_routine(p, a_panels, "col_stats", params, vec![73]);
        let s_panels: Vec<LocalPanel> =
            results.iter().map(|(_, st)| st.get(73).unwrap().clone()).collect();
        let s = gather_matrix(&s_panels).unwrap();
        for j in 0..3 {
            let mean: f64 = (0..40).map(|i| a_full.get(i, j)).sum::<f64>() / 40.0;
            let var: f64 =
                (0..40).map(|i| (a_full.get(i, j) - mean).powi(2)).sum::<f64>() / 40.0;
            assert!((s.get(j, 0) - mean).abs() < 1e-10, "mean col {j}");
            assert!((s.get(j, 1) - var.sqrt()).abs() < 1e-10, "std col {j}");
        }
    }

    #[test]
    fn lstsq_routine_recovers_planted_solution() {
        let p = 2;
        let (m, n) = (60u64, 5usize);
        let (a_full, mut a_panels) = seed(1, m as usize, n, p, 26);
        // y = A x_true (exact system -> zero residual)
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 + 1.0) * 0.5).collect();
        let y_full_vec = a_full.matvec(&x_true).unwrap();
        let y_full = DenseMatrix::from_vec(m as usize, 1, y_full_vec).unwrap();
        let y_panels = scatter_matrix(&meta(2, m, 1, p as u32), &y_full).unwrap();
        for (ap, yp) in a_panels.iter_mut().zip(y_panels) {
            ap.push(yp);
        }
        let params = ParamsBuilder::new().matrix("A", 1).matrix("y", 2).build();
        let results = run_routine(p, a_panels, "lstsq", params, vec![74]);
        let x_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(74).unwrap().clone()).collect();
        let x = gather_matrix(&x_panels).unwrap();
        for i in 0..n {
            assert!((x.get(i, 0) - x_true[i]).abs() < 1e-8, "x[{i}]");
        }
        let residual = results[0].0.outputs[0].1.as_f64().unwrap();
        assert!(residual < 1e-8, "residual {residual}");
    }

    #[test]
    fn unknown_routine_and_missing_params() {
        let p = 1;
        let (_, a_panels) = seed(1, 4, 2, p, 6);
        let results = run_mesh(p, move |mut mesh| {
            let mut store = MatrixStore::new();
            store.insert(a_panels[0][0].clone()).unwrap();
            let lib = ElemLib::new();
            let mut ctx = RoutineCtx {
                mesh: &mut mesh,
                owners: vec![0],
                store: &mut store,
                output_handles: &[9],
                backend: &NativeBackend,
                runtime: None,
                svd_pjrt: false,
                compute: Default::default(),
                cancel: CancelToken::new(),
                progress: ProgressSink::disabled(),
                wire_version: PROTOCOL_VERSION,
            };
            let unknown = lib.run("qr", &vec![], &mut ctx);
            let missing = lib.run("gemm", &vec![], &mut ctx);
            Ok((unknown.is_err(), missing.is_err()))
        })
        .unwrap();
        assert_eq!(results[0], (true, true));
    }

    #[test]
    fn registry_lists_all_routines_with_specs() {
        let lib = ElemLib::new();
        let reg = lib.registry().expect("elemlib publishes specs");
        assert_eq!(
            reg.names(),
            vec![
                "gemm",
                "truncated_svd",
                "condest",
                "fro_norm",
                "scale",
                "redistribute",
                "transpose",
                "add",
                "gramian",
                "col_stats",
                "lstsq",
            ]
        );
        for spec in reg.specs() {
            assert!(!spec.summary.is_empty(), "{} has no summary", spec.name);
        }
        // Cancellation/cost surfaces: a gemm on known shapes has a
        // plausible flop estimate.
        let spec = reg.get("gemm").unwrap().spec();
        let params = ParamsBuilder::new().matrix("A", 1).matrix("B", 2).build();
        let mk = |h: u64, rows: u64, cols: u64| MatrixMeta {
            handle: h,
            rows,
            cols,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: vec![0] },
        };
        let inputs = spec
            .validate(&params, |h| match h {
                1 => Some(mk(1, 100, 10)),
                2 => Some(mk(2, 10, 20)),
                _ => None,
            })
            .unwrap();
        let cost = spec.cost(&params, &inputs);
        assert_eq!(cost.flops, 2.0 * 100.0 * 10.0 * 20.0);
        assert!(cost.weight() > cost.flops);
    }
}
