//! `ElemLib` — the bundled MPI-library substitute, playing the role of
//! "Elemental + ARPACK wrapped by an ALI" in the paper's experiments.
//!
//! All routines are SPMD over the session mesh; node-local FLOPs go
//! through the pluggable GEMM backend (PJRT Pallas tiles in production)
//! and the fused PJRT Gram-matvec artifacts when available.
//!
//! Routines:
//! * `gemm(A, B) -> C` — distributed GEMM (Table 1's workhorse);
//! * `truncated_svd(A, k) -> U, S, V` — ARPACK-style thick-restart
//!   Lanczos on the Gram operator (Figs 3/4);
//! * `condest(A, probes?) -> cond` — the paper's §3.3 example routine;
//! * `fro_norm(A) -> norm`;
//! * `scale(A, alpha) -> B`;
//! * `redistribute(A, kind) -> B` — row-block ⇄ row-cyclic.

use crate::ali::{params, Library, RoutineCtx, RoutineOutput};
use crate::arpack::{lanczos_topk, LanczosOptions, SymOp};
use crate::comm::Mesh;
use crate::elemental::dist_gemm::{
    dist_frobenius, dist_gemm_with, dist_gram_matvec, DistGemmAlgo,
};
use crate::elemental::{redistribute::redistribute, LocalPanel};
use crate::linalg::DenseMatrix;
use crate::protocol::{LayoutDesc, LayoutKind, MatrixMeta, ParamValue, Params};
use crate::runtime::tiling::pjrt_gram_matvec;
use crate::{Error, Result};

/// The builtin library instance.
#[derive(Debug, Default)]
pub struct ElemLib;

impl ElemLib {
    pub fn new() -> ElemLib {
        ElemLib
    }
}

impl Library for ElemLib {
    fn name(&self) -> &str {
        "elemlib"
    }

    fn routines(&self) -> Vec<&'static str> {
        vec![
            "gemm",
            "truncated_svd",
            "condest",
            "fro_norm",
            "scale",
            "redistribute",
            "transpose",
            "add",
            "gramian",
            "col_stats",
            "lstsq",
        ]
    }

    fn run(
        &self,
        routine: &str,
        params: &Params,
        ctx: &mut RoutineCtx<'_>,
    ) -> Result<RoutineOutput> {
        match routine {
            "gemm" => run_gemm(params, ctx),
            "truncated_svd" => run_truncated_svd(params, ctx),
            "condest" => run_condest(params, ctx),
            "fro_norm" => run_fro_norm(params, ctx),
            "scale" => run_scale(params, ctx),
            "redistribute" => run_redistribute(params, ctx),
            "transpose" => run_transpose(params, ctx),
            "add" => run_add(params, ctx),
            "gramian" => run_gramian(params, ctx),
            "col_stats" => run_col_stats(params, ctx),
            "lstsq" => run_lstsq(params, ctx),
            other => Err(Error::Ali(format!(
                "elemlib has no routine {other:?} (available: {:?})",
                self.routines()
            ))),
        }
    }
}

fn run_gemm(p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
    let ha = params::get_matrix(p, "A")?;
    let hb = params::get_matrix(p, "B")?;
    let hc = ctx.output_handle(0)?;
    let alpha = params::get_f64_or(p, "alpha", 1.0)?;
    // Per-call overrides of the worker's `[compute]` defaults. SPMD-safe:
    // every rank receives the identical params frame.
    let mut opts = ctx.compute;
    if let Some(algo) = params::get_str_opt(p, "algo")? {
        opts.algo = DistGemmAlgo::parse(algo).map_err(|e| Error::Ali(e.to_string()))?;
    }
    let rows = params::get_i64_or(p, "panel_rows", opts.panel_rows as i64)?;
    if rows < 0 {
        return Err(Error::Ali("panel_rows must be >= 0".into()));
    }
    opts.panel_rows = rows as usize;
    let a = ctx.store.get(ha)?.clone();
    let b = ctx.store.get(hb)?.clone();
    let mut c = dist_gemm_with(ctx.mesh, &a, &b, hc, ctx.backend, &opts)?;
    if alpha != 1.0 {
        c.local_mut().scale(alpha);
    }
    let meta = c.meta.clone();
    ctx.store.insert(c)?;
    Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
}

/// Distributed Gram operator: w = Σ_ranks A_rᵀ(A_r v), one ring
/// all-reduce per application. Local halves go through the fused PJRT
/// artifacts with **device-resident cached panels** when available (the
/// panel is uploaded once; later iterations only ship v), else native
/// kernels.
struct DistGramOp<'a> {
    mesh: &'a mut Mesh,
    local: &'a DenseMatrix,
    runtime: Option<&'static crate::runtime::PjrtRuntime>,
    cached: Option<crate::runtime::tiling::CachedGramPanel>,
    pub applications: usize,
}

impl<'a> DistGramOp<'a> {
    /// `handle` keys the device-buffer cache (worker `FreeMatrix`
    /// invalidates it). The cache base also folds in the session rank:
    /// in this testbed all in-process workers share one PJRT runtime, so
    /// two ranks' panels of the same handle must not collide (separate
    /// worker *processes* would each have their own runtime).
    fn new(
        mesh: &'a mut Mesh,
        local: &'a DenseMatrix,
        runtime: Option<&'static crate::runtime::PjrtRuntime>,
        handle: u64,
        use_pjrt: bool,
    ) -> Result<DistGramOp<'a>> {
        let base = handle * 256 + mesh.rank() as u64;
        let runtime = if use_pjrt { runtime } else { None };
        let cached = match runtime {
            Some(rt) => crate::runtime::tiling::CachedGramPanel::new(rt, base, local)?,
            None => None,
        };
        Ok(DistGramOp { mesh, local, runtime, cached, applications: 0 })
    }
}

impl SymOp for DistGramOp<'_> {
    fn dim(&self) -> usize {
        self.local.cols()
    }

    fn apply(&mut self, v: &[f64]) -> Result<Vec<f64>> {
        self.applications += 1;
        let local = self.local;
        let rt = self.runtime;
        let cached = self.cached.as_ref();
        dist_gram_matvec(self.mesh, v, move |x| match (cached, rt) {
            (Some(panel), Some(rt)) => panel.apply(rt, x),
            (None, Some(rt)) => pjrt_gram_matvec(rt, local, x),
            (_, None) => {
                let t = local.matvec(x)?;
                local.matvec_t(&t)
            }
        })
    }
}

fn run_truncated_svd(p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
    let ha = params::get_matrix(p, "A")?;
    let k = params::get_i64(p, "k")? as usize;
    let tol = params::get_f64_or(p, "tol", 1e-10)?;
    let hu = ctx.output_handle(0)?;
    let hs = ctx.output_handle(1)?;
    let hv = ctx.output_handle(2)?;

    let a = ctx.store.get(ha)?;
    let (m, n) = (a.meta.rows, a.meta.cols);
    if k == 0 || k as u64 > n.min(m) {
        return Err(Error::Numerical(format!("truncated_svd: k={k} out of range for {m}x{n}")));
    }
    let a_local = a.local().clone();
    let a_meta = a.meta.clone();

    // SPMD Lanczos: every rank runs the identical iteration; the only
    // cross-rank op is the all-reduce inside the Gram operator, which is
    // deterministic, so all ranks hold identical basis/Ritz state.
    let result = {
        let mut op = DistGramOp::new(ctx.mesh, &a_local, ctx.runtime, ha, ctx.svd_pjrt)?;
        lanczos_topk(&mut op, k, &LanczosOptions { tol, ..Default::default() })?
    };

    let mut sigma = Vec::with_capacity(k);
    let mut v_full = DenseMatrix::zeros(n as usize, k);
    for (j, (theta, vec)) in result.eigenvalues.iter().zip(&result.eigenvectors).enumerate() {
        sigma.push(theta.max(0.0).sqrt());
        for i in 0..n as usize {
            v_full.set(i, j, vec[i]);
        }
    }

    // U_local = A_local V Σ⁻¹ (rank-deficient columns zeroed).
    let mut u_local = ctx.backend.gemm(&a_local, &v_full)?;
    for j in 0..k {
        let s = sigma[j];
        let inv = if s > 1e-12 { 1.0 / s } else { 0.0 };
        for i in 0..u_local.rows() {
            let cur = u_local.get(i, j);
            u_local.set(i, j, cur * inv);
        }
    }

    let owners = ctx.owners.clone();
    let rank = ctx.mesh.rank() as u32;
    let layout = |_rows: u64| LayoutDesc { kind: LayoutKind::RowBlock, owners: owners.clone() };

    // U: same row distribution as A.
    let u_meta = MatrixMeta { handle: hu, rows: m, cols: k as u64, layout: a_meta.layout.clone() };
    let u_panel = LocalPanel::from_local(u_meta.clone(), a_meta_slot(&a_meta, rank)?, u_local)?;

    // S (k x 1) and V (n x k) are replicated on every rank; store each
    // rank's RowBlock slice so the client can fetch them like any matrix.
    let s_meta = MatrixMeta { handle: hs, rows: k as u64, cols: 1, layout: layout(k as u64) };
    let s_panel = slice_replicated(&s_meta, rank, |i, _| sigma[i as usize])?;
    let v_meta = MatrixMeta { handle: hv, rows: n, cols: k as u64, layout: layout(n) };
    let v_panel = slice_replicated(&v_meta, rank, |i, j| v_full.get(i as usize, j as usize))?;

    let metas = vec![u_meta, s_meta, v_meta];
    ctx.store.insert(u_panel)?;
    ctx.store.insert(s_panel)?;
    ctx.store.insert(v_panel)?;

    Ok(RoutineOutput {
        outputs: vec![
            ("matvecs".into(), ParamValue::I64(result.matvecs as i64)),
            ("restarts".into(), ParamValue::I64(result.restarts as i64)),
        ],
        new_matrices: metas,
    })
}

/// Slot of this rank in a matrix's owner list (rank order == slot order).
fn a_meta_slot(meta: &MatrixMeta, rank: u32) -> Result<u32> {
    if (rank as usize) < meta.layout.owners.len() {
        Ok(rank)
    } else {
        Err(Error::Server(format!("rank {rank} outside owner list of handle {}", meta.handle)))
    }
}

/// Build this rank's RowBlock panel of a replicated matrix defined by a
/// closure over (global_row, col).
fn slice_replicated(
    meta: &MatrixMeta,
    rank: u32,
    f: impl Fn(u64, u64) -> f64,
) -> Result<LocalPanel> {
    let mut panel = LocalPanel::alloc(meta.clone(), rank)?;
    let layout = panel.layout();
    let rows: Vec<u64> = layout.rows_of_slot(rank).collect();
    for r in rows {
        let row: Vec<f64> = (0..meta.cols).map(|c| f(r, c)).collect();
        panel.set_row(r, &row)?;
    }
    Ok(panel)
}

fn run_condest(p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
    let ha = params::get_matrix(p, "A")?;
    let probes = params::get_i64_or(p, "probes", 8)? as usize;
    let a = ctx.store.get(ha)?;
    let n = a.meta.cols as usize;
    let a_local = a.local().clone();
    let k = probes.clamp(2, n);
    let result = {
        let mut op = DistGramOp::new(ctx.mesh, &a_local, ctx.runtime, ha, ctx.svd_pjrt)?;
        let opts =
            LanczosOptions { max_basis: (4 * k + 20).min(n), ..Default::default() };
        lanczos_topk(&mut op, k, &opts)?
    };
    let smax = result.eigenvalues.first().copied().unwrap_or(0.0).max(0.0).sqrt();
    let smin = result.eigenvalues.last().copied().unwrap_or(0.0).max(0.0).sqrt();
    let cond = if smin <= 1e-300 { f64::INFINITY } else { smax / smin };
    Ok(RoutineOutput {
        outputs: vec![("condest".into(), ParamValue::F64(cond))],
        new_matrices: vec![],
    })
}

fn run_fro_norm(p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
    let ha = params::get_matrix(p, "A")?;
    let a = ctx.store.get(ha)?.clone();
    let norm = dist_frobenius(ctx.mesh, &a)?;
    Ok(RoutineOutput {
        outputs: vec![("fro_norm".into(), ParamValue::F64(norm))],
        new_matrices: vec![],
    })
}

fn run_scale(p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
    let ha = params::get_matrix(p, "A")?;
    let alpha = params::get_f64(p, "alpha")?;
    let hb = ctx.output_handle(0)?;
    let a = ctx.store.get(ha)?;
    let mut local = a.local().clone();
    local.scale(alpha);
    let meta = MatrixMeta { handle: hb, ..a.meta.clone() };
    let panel = LocalPanel::from_local(meta.clone(), a.slot, local)?;
    ctx.store.insert(panel)?;
    Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
}

fn run_redistribute(p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
    let ha = params::get_matrix(p, "A")?;
    let kind = match params::get_str(p, "kind")? {
        "row_block" => LayoutKind::RowBlock,
        "row_cyclic" => LayoutKind::RowCyclic,
        other => return Err(Error::Ali(format!("unknown layout kind {other:?}"))),
    };
    let hb = ctx.output_handle(0)?;
    let a = ctx.store.get(ha)?.clone();
    let out = redistribute(ctx.mesh, &a, hb, kind)?;
    let meta = out.meta.clone();
    ctx.store.insert(out)?;
    Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
}

fn run_transpose(p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
    let ha = params::get_matrix(p, "A")?;
    let hb = ctx.output_handle(0)?;
    let a = ctx.store.get(ha)?.clone();
    if a.meta.layout.kind != LayoutKind::RowBlock {
        return Err(Error::Shape("transpose requires RowBlock input".into()));
    }
    let out = crate::elemental::transpose::dist_transpose(ctx.mesh, &a, hb)?;
    let meta = out.meta.clone();
    ctx.store.insert(out)?;
    Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
}

fn run_add(p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
    // C = alpha A + beta B (same shape, same layout — purely local)
    let ha = params::get_matrix(p, "A")?;
    let hb = params::get_matrix(p, "B")?;
    let alpha = params::get_f64_or(p, "alpha", 1.0)?;
    let beta = params::get_f64_or(p, "beta", 1.0)?;
    let hc = ctx.output_handle(0)?;
    let a = ctx.store.get(ha)?;
    let b = ctx.store.get(hb)?;
    if a.meta.rows != b.meta.rows || a.meta.cols != b.meta.cols || a.meta.layout != b.meta.layout
    {
        return Err(Error::Shape("add: shape/layout mismatch".into()));
    }
    let mut local = a.local().clone();
    local.scale(alpha);
    for (dst, src) in local.data_mut().iter_mut().zip(b.local().data()) {
        *dst += beta * src;
    }
    let meta = MatrixMeta { handle: hc, ..a.meta.clone() };
    let slot = a.slot;
    let panel = LocalPanel::from_local(meta.clone(), slot, local)?;
    ctx.store.insert(panel)?;
    Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
}

fn run_gramian(p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
    // G = AᵀA (n x n): local gemm_tn + all-reduce, stored RowBlock.
    // MLlib's computeGramianMatrix analogue — n must be modest.
    let ha = params::get_matrix(p, "A")?;
    let hg = ctx.output_handle(0)?;
    let a = ctx.store.get(ha)?;
    let n = a.meta.cols as usize;
    let mut g = crate::linalg::gemm::gemm_tn(a.local(), a.local())?.into_vec();
    crate::comm::collectives::allreduce_sum(
        ctx.mesh,
        &mut g,
        crate::comm::collectives::AllReduceAlgo::Ring,
    )?;
    let g_full = DenseMatrix::from_vec(n, n, g)?;
    let meta = MatrixMeta {
        handle: hg,
        rows: n as u64,
        cols: n as u64,
        layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: ctx.owners.clone() },
    };
    let rank = ctx.mesh.rank() as u32;
    let panel = slice_replicated(&meta, rank, |i, j| g_full.get(i as usize, j as usize))?;
    ctx.store.insert(panel)?;
    Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
}

fn run_col_stats(p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
    // column means and (population) stddevs -> n x 2 matrix [mean, std]
    let ha = params::get_matrix(p, "A")?;
    let hs = ctx.output_handle(0)?;
    let a = ctx.store.get(ha)?;
    let n = a.meta.cols as usize;
    let m = a.meta.rows as f64;
    let mut acc = vec![0.0; 2 * n]; // sums then sumsq
    for (_, row) in a.iter_rows() {
        for (j, &v) in row.iter().enumerate() {
            acc[j] += v;
            acc[n + j] += v * v;
        }
    }
    crate::comm::collectives::allreduce_sum(
        ctx.mesh,
        &mut acc,
        crate::comm::collectives::AllReduceAlgo::Ring,
    )?;
    let meta = MatrixMeta {
        handle: hs,
        rows: n as u64,
        cols: 2,
        layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: ctx.owners.clone() },
    };
    let rank = ctx.mesh.rank() as u32;
    let panel = slice_replicated(&meta, rank, |i, j| {
        let mean = acc[i as usize] / m;
        if j == 0 {
            mean
        } else {
            (acc[n + i as usize] / m - mean * mean).max(0.0).sqrt()
        }
    })?;
    ctx.store.insert(panel)?;
    Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
}

fn run_lstsq(p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
    // min_x ||A x - y||_2 via normal equations + Cholesky:
    //   G = AᵀA (all-reduced), b = Aᵀy (all-reduced), G x = b locally.
    // The classic Elemental-style tall-skinny least-squares path — the
    // regression workload the paper's intro motivates.
    let ha = params::get_matrix(p, "A")?;
    let hy = params::get_matrix(p, "y")?;
    let ridge = params::get_f64_or(p, "ridge", 0.0)?;
    let hx = ctx.output_handle(0)?;
    let a = ctx.store.get(ha)?;
    let y = ctx.store.get(hy)?;
    if y.meta.rows != a.meta.rows || y.meta.cols != 1 || y.meta.layout != a.meta.layout {
        return Err(Error::Shape("lstsq: y must be m x 1 with A's layout".into()));
    }
    let n = a.meta.cols as usize;
    let y_local: Vec<f64> = (0..y.local_rows()).map(|i| y.local().get(i, 0)).collect();

    let mut g = crate::linalg::gemm::gemm_tn(a.local(), a.local())?.into_vec();
    let mut b = a.local().matvec_t(&y_local)?;
    crate::comm::collectives::allreduce_sum(
        ctx.mesh,
        &mut g,
        crate::comm::collectives::AllReduceAlgo::Ring,
    )?;
    crate::comm::collectives::allreduce_sum(
        ctx.mesh,
        &mut b,
        crate::comm::collectives::AllReduceAlgo::Ring,
    )?;
    let mut g_full = DenseMatrix::from_vec(n, n, g)?;
    if ridge > 0.0 {
        for i in 0..n {
            g_full.set(i, i, g_full.get(i, i) + ridge);
        }
    }
    let x = crate::linalg::cholesky::spd_solve(&g_full, &b)?;

    // residual norm: local ||A_loc x - y_loc||^2, all-reduced
    let ax = a.local().matvec(&x)?;
    let mut res = vec![ax
        .iter()
        .zip(&y_local)
        .map(|(p, q)| (p - q) * (p - q))
        .sum::<f64>()];
    crate::comm::collectives::allreduce_sum(
        ctx.mesh,
        &mut res,
        crate::comm::collectives::AllReduceAlgo::Ring,
    )?;

    let meta = MatrixMeta {
        handle: hx,
        rows: n as u64,
        cols: 1,
        layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: ctx.owners.clone() },
    };
    let rank = ctx.mesh.rank() as u32;
    let panel = slice_replicated(&meta, rank, |i, _| x[i as usize])?;
    ctx.store.insert(panel)?;
    Ok(RoutineOutput {
        outputs: vec![("residual".into(), ParamValue::F64(res[0].sqrt()))],
        new_matrices: vec![meta],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ali::params::ParamsBuilder;
    use crate::comm::run_mesh;
    use crate::elemental::dist_gemm::NativeBackend;
    use crate::elemental::panel::{gather_matrix, scatter_matrix};
    use crate::elemental::MatrixStore;
    use crate::workload::random_matrix;
    use std::sync::Arc;

    /// Drive an elemlib routine SPMD over an in-process mesh with each
    /// rank's store pre-seeded by `seed_panels`.
    fn run_routine(
        p: usize,
        seed_panels: Vec<Vec<LocalPanel>>, // [rank][panels]
        routine: &'static str,
        params: Params,
        output_handles: Vec<u64>,
    ) -> Vec<(RoutineOutput, MatrixStore)> {
        let seed = Arc::new(seed_panels);
        let params = Arc::new(params);
        let handles = Arc::new(output_handles);
        run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            let mut store = MatrixStore::new();
            for panel in &seed[rank] {
                store.insert(panel.clone()).unwrap();
            }
            let lib = ElemLib::new();
            let mut ctx = RoutineCtx {
                mesh: &mut mesh,
                owners: (0..p as u32).collect(),
                store: &mut store,
                output_handles: &handles,
                backend: &NativeBackend,
                runtime: None,
                svd_pjrt: false,
                compute: Default::default(),
            };
            let out = lib.run(routine, &params, &mut ctx)?;
            Ok((out, store))
        })
        .unwrap()
    }

    fn meta(handle: u64, rows: u64, cols: u64, p: u32) -> MatrixMeta {
        MatrixMeta {
            handle,
            rows,
            cols,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: (0..p).collect() },
        }
    }

    fn seed(handle: u64, rows: usize, cols: usize, p: usize, s: u64) -> (DenseMatrix, Vec<Vec<LocalPanel>>) {
        let full = DenseMatrix::from_vec(rows, cols, random_matrix(s, rows, cols)).unwrap();
        let panels = scatter_matrix(&meta(handle, rows as u64, cols as u64, p as u32), &full).unwrap();
        (full, panels.into_iter().map(|x| vec![x]).collect())
    }

    #[test]
    fn gemm_routine_end_to_end() {
        let p = 3;
        let (a_full, mut a_panels) = seed(1, 31, 7, p, 1);
        let (b_full, b_panels) = seed(2, 7, 5, p, 2);
        for (ap, bp) in a_panels.iter_mut().zip(b_panels) {
            ap.extend(bp);
        }
        let params = ParamsBuilder::new().matrix("A", 1).matrix("B", 2).build();
        let results = run_routine(p, a_panels, "gemm", params, vec![100]);
        let c_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(100).unwrap().clone()).collect();
        let c = gather_matrix(&c_panels).unwrap();
        let want = crate::linalg::gemm::gemm(&a_full, &b_full).unwrap();
        assert!(c.max_abs_diff(&want).unwrap() < 1e-10);
        assert_eq!(results[0].0.new_matrices.len(), 1);
        assert_eq!(results[0].0.new_matrices[0].handle, 100);
    }

    #[test]
    fn gemm_routine_algo_params() {
        // "ring" and "allgather" via routine params are bit-identical;
        // a bogus algo is an Ali error.
        let p = 3;
        let (_, mut a_panels) = seed(1, 19, 7, p, 31);
        let (_, b_panels) = seed(2, 7, 5, p, 32);
        for (ap, bp) in a_panels.iter_mut().zip(b_panels) {
            ap.extend(bp);
        }
        let mut gathered = Vec::new();
        for algo in ["ring", "allgather"] {
            let params = ParamsBuilder::new()
                .matrix("A", 1)
                .matrix("B", 2)
                .str("algo", algo)
                .i64("panel_rows", 2)
                .build();
            let results = run_routine(p, a_panels.clone(), "gemm", params, vec![100]);
            let c_panels: Vec<LocalPanel> =
                results.iter().map(|(_, s)| s.get(100).unwrap().clone()).collect();
            gathered.push(gather_matrix(&c_panels).unwrap());
        }
        assert_eq!(gathered[0], gathered[1], "ring vs allgather through the routine layer");

        let params = ParamsBuilder::new()
            .matrix("A", 1)
            .matrix("B", 2)
            .str("algo", "summa3d")
            .build();
        let results = run_routine_fallible(p, a_panels, "gemm", params, vec![100]);
        assert!(results.iter().all(|r| r.is_err()));
    }

    /// Like `run_routine` but returning each rank's `Result` (for tests
    /// exercising SPMD error paths).
    fn run_routine_fallible(
        p: usize,
        seed_panels: Vec<Vec<LocalPanel>>,
        routine: &'static str,
        params: Params,
        output_handles: Vec<u64>,
    ) -> Vec<std::result::Result<RoutineOutput, String>> {
        let seed = Arc::new(seed_panels);
        let params = Arc::new(params);
        let handles = Arc::new(output_handles);
        run_mesh(p, move |mut mesh| {
            let rank = mesh.rank();
            let mut store = MatrixStore::new();
            for panel in &seed[rank] {
                store.insert(panel.clone()).unwrap();
            }
            let lib = ElemLib::new();
            let mut ctx = RoutineCtx {
                mesh: &mut mesh,
                owners: (0..p as u32).collect(),
                store: &mut store,
                output_handles: &handles,
                backend: &NativeBackend,
                runtime: None,
                svd_pjrt: false,
                compute: Default::default(),
            };
            Ok(lib.run(routine, &params, &mut ctx).map_err(|e| e.to_string()))
        })
        .unwrap()
    }

    #[test]
    fn truncated_svd_routine_matches_local_reference() {
        let p = 2;
        let (a_full, a_panels) = seed(1, 60, 16, p, 3);
        let params = ParamsBuilder::new().matrix("A", 1).i64("k", 4).build();
        let results = run_routine(p, a_panels, "truncated_svd", params, vec![10, 11, 12]);

        // reference via local ARPACK-substitute
        let want =
            crate::arpack::truncated_svd_local(&a_full, 4, &LanczosOptions::default()).unwrap();

        // singular values from the distributed S
        let s_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(11).unwrap().clone()).collect();
        let s = gather_matrix(&s_panels).unwrap();
        for i in 0..4 {
            assert!(
                (s.get(i, 0) - want.singular_values[i]).abs() < 1e-6,
                "sigma_{i}: {} vs {}",
                s.get(i, 0),
                want.singular_values[i]
            );
        }

        // U, V reproduce A V = U Σ
        let u_panels: Vec<LocalPanel> =
            results.iter().map(|(_, st)| st.get(10).unwrap().clone()).collect();
        let v_panels: Vec<LocalPanel> =
            results.iter().map(|(_, st)| st.get(12).unwrap().clone()).collect();
        let u = gather_matrix(&u_panels).unwrap();
        let v = gather_matrix(&v_panels).unwrap();
        let av = crate::linalg::gemm::gemm(&a_full, &v).unwrap();
        for j in 0..4 {
            for i in 0..60 {
                let lhs = av.get(i, j);
                let rhs = s.get(j, 0) * u.get(i, j);
                assert!((lhs - rhs).abs() < 1e-6, "AV=UΣ at ({i},{j}): {lhs} vs {rhs}");
            }
        }
        // scalar outputs present on rank 0
        assert!(results[0].0.outputs.iter().any(|(k, _)| k == "matvecs"));
    }

    #[test]
    fn fro_norm_and_scale() {
        let p = 2;
        let (a_full, a_panels) = seed(1, 12, 3, p, 4);
        let params = ParamsBuilder::new().matrix("A", 1).build();
        let results = run_routine(p, a_panels.clone(), "fro_norm", params, vec![]);
        let (out, _) = &results[0];
        let got = out.outputs[0].1.as_f64().unwrap();
        assert!((got - a_full.frobenius_norm()).abs() < 1e-10);

        let params = ParamsBuilder::new().matrix("A", 1).f64("alpha", -2.0).build();
        let results = run_routine(p, a_panels, "scale", params, vec![50]);
        let b_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(50).unwrap().clone()).collect();
        let b = gather_matrix(&b_panels).unwrap();
        assert!((b.get(3, 1) + 2.0 * a_full.get(3, 1)).abs() < 1e-12);
    }

    #[test]
    fn redistribute_routine() {
        let p = 3;
        let (a_full, a_panels) = seed(1, 17, 2, p, 5);
        let params = ParamsBuilder::new().matrix("A", 1).str("kind", "row_cyclic").build();
        let results = run_routine(p, a_panels, "redistribute", params, vec![60]);
        let b_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(60).unwrap().clone()).collect();
        assert_eq!(b_panels[0].meta.layout.kind, LayoutKind::RowCyclic);
        let b = gather_matrix(&b_panels).unwrap();
        assert_eq!(b, a_full);
    }

    #[test]
    fn condest_identity_is_one() {
        let p = 2;
        let n = 12;
        let full = DenseMatrix::identity(n);
        let panels = scatter_matrix(&meta(1, n as u64, n as u64, p as u32), &full).unwrap();
        let params = ParamsBuilder::new().matrix("A", 1).i64("probes", 6).build();
        let results = run_routine(
            p,
            panels.into_iter().map(|x| vec![x]).collect(),
            "condest",
            params,
            vec![],
        );
        let got = results[0].0.outputs[0].1.as_f64().unwrap();
        assert!((got - 1.0).abs() < 1e-6, "condest {got}");
    }

    #[test]
    fn transpose_routine_matches_local() {
        let p = 3;
        let (a_full, a_panels) = seed(1, 14, 9, p, 21);
        let params = ParamsBuilder::new().matrix("A", 1).build();
        let results = run_routine(p, a_panels, "transpose", params, vec![70]);
        // cell-wise assembled panels: reassemble from local storage
        let mut bt = DenseMatrix::zeros(9, 14);
        for (_, st) in &results {
            let panel = st.get(70).unwrap();
            let layout = panel.layout();
            for li in 0..panel.local_rows() {
                let gr = layout.global_index(panel.slot, li as u64) as usize;
                bt.row_mut(gr).copy_from_slice(panel.local().row(li));
            }
        }
        assert_eq!(bt, a_full.transpose());
    }

    #[test]
    fn add_routine_linear_combination() {
        let p = 2;
        let (a_full, mut a_panels) = seed(1, 10, 4, p, 22);
        let (b_full, b_panels) = seed(2, 10, 4, p, 23);
        for (ap, bp) in a_panels.iter_mut().zip(b_panels) {
            ap.extend(bp);
        }
        let params = ParamsBuilder::new()
            .matrix("A", 1)
            .matrix("B", 2)
            .f64("alpha", 2.0)
            .f64("beta", -0.5)
            .build();
        let results = run_routine(p, a_panels, "add", params, vec![71]);
        let c_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(71).unwrap().clone()).collect();
        let c = gather_matrix(&c_panels).unwrap();
        for i in 0..10 {
            for j in 0..4 {
                let want = 2.0 * a_full.get(i, j) - 0.5 * b_full.get(i, j);
                assert!((c.get(i, j) - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gramian_routine_matches_local() {
        let p = 2;
        let (a_full, a_panels) = seed(1, 30, 6, p, 24);
        let params = ParamsBuilder::new().matrix("A", 1).build();
        let results = run_routine(p, a_panels, "gramian", params, vec![72]);
        let g_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(72).unwrap().clone()).collect();
        let g = gather_matrix(&g_panels).unwrap();
        let want = crate::linalg::gemm::gemm_tn(&a_full, &a_full).unwrap();
        assert!(g.max_abs_diff(&want).unwrap() < 1e-9);
    }

    #[test]
    fn col_stats_routine() {
        let p = 2;
        let (a_full, a_panels) = seed(1, 40, 3, p, 25);
        let params = ParamsBuilder::new().matrix("A", 1).build();
        let results = run_routine(p, a_panels, "col_stats", params, vec![73]);
        let s_panels: Vec<LocalPanel> =
            results.iter().map(|(_, st)| st.get(73).unwrap().clone()).collect();
        let s = gather_matrix(&s_panels).unwrap();
        for j in 0..3 {
            let mean: f64 = (0..40).map(|i| a_full.get(i, j)).sum::<f64>() / 40.0;
            let var: f64 =
                (0..40).map(|i| (a_full.get(i, j) - mean).powi(2)).sum::<f64>() / 40.0;
            assert!((s.get(j, 0) - mean).abs() < 1e-10, "mean col {j}");
            assert!((s.get(j, 1) - var.sqrt()).abs() < 1e-10, "std col {j}");
        }
    }

    #[test]
    fn lstsq_routine_recovers_planted_solution() {
        let p = 2;
        let (m, n) = (60u64, 5usize);
        let (a_full, mut a_panels) = seed(1, m as usize, n, p, 26);
        // y = A x_true (exact system -> zero residual)
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 + 1.0) * 0.5).collect();
        let y_full_vec = a_full.matvec(&x_true).unwrap();
        let y_full =
            DenseMatrix::from_vec(m as usize, 1, y_full_vec).unwrap();
        let y_panels = scatter_matrix(&meta(2, m, 1, p as u32), &y_full).unwrap();
        for (ap, yp) in a_panels.iter_mut().zip(y_panels) {
            ap.push(yp);
        }
        let params = ParamsBuilder::new().matrix("A", 1).matrix("y", 2).build();
        let results = run_routine(p, a_panels, "lstsq", params, vec![74]);
        let x_panels: Vec<LocalPanel> =
            results.iter().map(|(_, s)| s.get(74).unwrap().clone()).collect();
        let x = gather_matrix(&x_panels).unwrap();
        for i in 0..n {
            assert!((x.get(i, 0) - x_true[i]).abs() < 1e-8, "x[{i}]");
        }
        let residual = results[0].0.outputs[0].1.as_f64().unwrap();
        assert!(residual < 1e-8, "residual {residual}");
    }

    #[test]
    fn unknown_routine_and_missing_params() {
        let p = 1;
        let (_, a_panels) = seed(1, 4, 2, p, 6);
        let results = run_mesh(p, move |mut mesh| {
            let mut store = MatrixStore::new();
            store.insert(a_panels[0][0].clone()).unwrap();
            let lib = ElemLib::new();
            let mut ctx = RoutineCtx {
                mesh: &mut mesh,
                owners: vec![0],
                store: &mut store,
                output_handles: &[9],
                backend: &NativeBackend,
                runtime: None,
                svd_pjrt: false,
                compute: Default::default(),
            };
            let unknown = lib.run("qr", &vec![], &mut ctx);
            let missing = lib.run("gemm", &vec![], &mut ctx);
            Ok((unknown.is_err(), missing.is_err()))
        })
        .unwrap();
        assert_eq!(results[0], (true, true));
    }
}
