//! Alchemist-Library Interface (ALI) — the generic calling convention
//! through which the server invokes library routines (paper §2.3/§3.5).
//!
//! An ALI in the original is a C/C++ shared object implementing `Library`
//! and `Parameters` headers, `dlopen`ed at runtime. In this reproduction a
//! library is a Rust [`Library`] trait object produced by a registered
//! *factory*; the "dynamic load" surface is preserved — clients register
//! libraries by (name, path) where the path uses the `builtin:` scheme
//! (e.g. `builtin:elemlib`) or names a factory installed with
//! [`registry::install_factory`]. Real `dlopen` of foreign ABIs is out of
//! scope (documented in DESIGN.md).
//!
//! Since the typed routine engine, a library's routines are first-class
//! [`Routine`] objects: each carries a [`spec::RoutineSpec`] (typed param
//! schema, input shape rules, output roles, cost estimate) registered in
//! a [`registry::RoutineRegistry`]. The driver validates submissions
//! against the same specs *before* sched admission and uses the cost
//! estimate for its per-session in-flight cap; workers re-validate on
//! entry (SPMD-deterministically) before any collective is touched.

pub mod elemlib;
pub mod params;
pub mod registry;
pub mod routines;
pub mod spec;
pub mod task;

use crate::comm::Mesh;
use crate::elemental::dist_gemm::{DistGemmOptions, GemmBackend};
use crate::elemental::MatrixStore;
use crate::protocol::{MatrixMeta, Params};
use crate::Result;

pub use task::{CancelToken, ProgressSink, StatusBoard};

/// Everything a routine needs from its hosting worker, SPMD-style: each
/// session worker constructs an identical ctx (modulo rank) and the
/// routine runs collectively.
pub struct RoutineCtx<'a> {
    /// Session communicator (rank == slot index in matrix layouts).
    pub mesh: &'a mut Mesh,
    /// Worker ids of the session, in rank order (for output metadata).
    pub owners: Vec<u32>,
    /// This worker's panel store.
    pub store: &'a mut MatrixStore,
    /// Handles pre-assigned by the driver for distributed outputs, in the
    /// order the routine allocates them.
    pub output_handles: &'a [u64],
    /// Node-local GEMM provider (PJRT Pallas tiles or native).
    pub backend: &'a dyn GemmBackend,
    /// PJRT runtime for fused artifacts (None => native-only mode).
    pub runtime: Option<&'static crate::runtime::PjrtRuntime>,
    /// Route the SVD Gram operator through PJRT (`server.svd_backend`);
    /// false = native kernels (the CPU-testbed default, see config.rs).
    pub svd_pjrt: bool,
    /// Distributed-GEMM defaults from the `[compute]` config (routines
    /// may override per call via `algo` / `panel_rows` params).
    pub compute: DistGemmOptions,
    /// Cooperative cancel flag for this invocation. Routines act on it
    /// only at collective boundaries, after cross-rank agreement (see
    /// [`task`] module docs) — never by bailing out locally.
    pub cancel: CancelToken,
    /// Live `(phase, fraction)` reporting channel; rank 0's reports feed
    /// `PollJob`'s `Running { phase, progress }`.
    pub progress: ProgressSink,
    /// Client protocol version negotiated for the session. Routines
    /// consult it before emitting wire shapes old clients cannot decode
    /// (e.g. `Replicated` output layouts need ≥ v6).
    pub wire_version: u16,
}

impl RoutineCtx<'_> {
    /// Take the i-th pre-assigned output handle.
    pub fn output_handle(&self, i: usize) -> Result<u64> {
        self.output_handles.get(i).copied().ok_or_else(|| {
            crate::Error::Ali(format!(
                "routine needs output handle #{i} but only {} were pre-assigned",
                self.output_handles.len()
            ))
        })
    }
}

/// What a routine returns: scalar outputs (rank 0's are reported to the
/// client) and metadata for each new distributed matrix it stored.
#[derive(Debug, Clone, Default)]
pub struct RoutineOutput {
    pub outputs: Params,
    pub new_matrices: Vec<MatrixMeta>,
}

/// One typed routine: a spec (schema + shape rules + cost) plus the SPMD
/// body. Implementations live in [`routines`] and are registered in the
/// library's [`registry::RoutineRegistry`].
pub trait Routine: Send + Sync {
    fn spec(&self) -> &spec::RoutineSpec;

    /// Invoke collectively; params have already been validated against
    /// [`Routine::spec`] by the caller ([`Library::run`]).
    fn run(&self, params: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput>;
}

/// A loadable MPI-library wrapper (the ALI `Library` header analogue).
pub trait Library: Send + Sync {
    fn name(&self) -> &str;

    /// List of routines (for error messages / introspection).
    fn routines(&self) -> Vec<&'static str>;

    /// The typed routine table, when this library publishes one. Drives
    /// driver-side validation, cost-aware admission and
    /// `DescribeRoutines`; `None` (the default, for foreign ALIs) means
    /// submissions are validated on the workers only, as before.
    fn registry(&self) -> Option<&registry::RoutineRegistry> {
        None
    }

    /// Invoke `routine` collectively. Every session worker calls this with
    /// its own ctx; implementations communicate via `ctx.mesh`.
    fn run(&self, routine: &str, params: &Params, ctx: &mut RoutineCtx<'_>)
        -> Result<RoutineOutput>;
}
