//! Typed routine specifications — the introspectable half of the ALI
//! calling convention (paper §2.3/§3.5, plus the routine-introspection
//! surface the Alchemist deployment papers motivate).
//!
//! A [`RoutineSpec`] declares a routine's parameter schema (names, types,
//! defaults, ranges), its input-matrix shape rules, its distributed
//! outputs, and a FLOP/byte cost estimate. The same spec is evaluated in
//! two places:
//!
//! * **driver-side**, before sched admission: malformed submissions fail
//!   at `SubmitRoutine` time without ever consuming a job slot or the
//!   worker group;
//! * **worker-side**, on entry to the library: every rank validates the
//!   identical params frame against the identical store metadata, so a
//!   rejection is SPMD-deterministic (all ranks refuse before any
//!   collective is entered).
//!
//! The serializable subset (names/types/defaults/docs) crosses the wire
//! as [`RoutineDescriptor`] in the v6 `DescribeRoutines` reply; shape
//! rules and cost functions stay server-side.

use crate::protocol::{
    MatrixMeta, ParamDescriptor, ParamType, ParamValue, Params, RoutineDescriptor,
};
use crate::{Error, Result};

/// Estimated resource footprint of one routine invocation, derived from
/// the spec's cost function over the resolved input shapes. The
/// scheduler's per-session in-flight cost cap compares
/// [`CostEstimate::weight`] sums against `sched.max_inflight_cost_per_session`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CostEstimate {
    /// Floating-point operations across the worker group.
    pub flops: f64,
    /// Bytes touched/moved (panel reads, collective traffic).
    pub bytes: f64,
}

impl CostEstimate {
    /// Scalar admission weight: flops plus bytes, both counted once.
    /// Crude, but monotone in problem size — which is all the in-flight
    /// cap needs.
    pub fn weight(&self) -> f64 {
        self.flops + self.bytes
    }
}

/// Cost function over (params, resolved input metas). Input metas are
/// `(param_name, meta)` pairs in spec order.
pub type CostFn = fn(&Params, &[(&str, &MatrixMeta)]) -> CostEstimate;

fn zero_cost(_: &Params, _: &[(&str, &MatrixMeta)]) -> CostEstimate {
    CostEstimate::default()
}

/// Value constraint on one parameter.
#[derive(Debug, Clone, Copy)]
pub enum ParamRange {
    Any,
    I64 { min: i64, max: i64 },
    F64 { min: f64, max: f64 },
    /// String must be one of these spellings.
    OneOf(&'static [&'static str]),
    /// String must parse as a process-grid spec: "auto" or "RxC" with
    /// positive factors (see [`crate::elemental::GridSpec::parse`]).
    /// Whether the shape tiles the worker group is only known at run
    /// time; pre-admission validation checks the spelling.
    Grid,
}

/// One declared parameter.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: &'static str,
    pub ty: ParamType,
    pub required: bool,
    /// Default applied by the routine when the parameter is omitted
    /// (documentation; specs do not inject it into the params list).
    pub default: Option<ParamValue>,
    pub range: ParamRange,
    pub doc: &'static str,
}

impl ParamSpec {
    /// Required matrix-handle parameter (an input role).
    pub fn matrix(name: &'static str, doc: &'static str) -> ParamSpec {
        ParamSpec {
            name,
            ty: ParamType::Matrix,
            required: true,
            default: None,
            range: ParamRange::Any,
            doc,
        }
    }

    /// Required i64 parameter.
    pub fn i64_req(name: &'static str, doc: &'static str) -> ParamSpec {
        ParamSpec {
            name,
            ty: ParamType::I64,
            required: true,
            default: None,
            range: ParamRange::Any,
            doc,
        }
    }

    /// Optional i64 parameter with a default.
    pub fn i64_opt(name: &'static str, default: i64, doc: &'static str) -> ParamSpec {
        ParamSpec {
            name,
            ty: ParamType::I64,
            required: false,
            default: Some(ParamValue::I64(default)),
            range: ParamRange::Any,
            doc,
        }
    }

    /// Required f64 parameter.
    pub fn f64_req(name: &'static str, doc: &'static str) -> ParamSpec {
        ParamSpec {
            name,
            ty: ParamType::F64,
            required: true,
            default: None,
            range: ParamRange::Any,
            doc,
        }
    }

    /// Optional f64 parameter with a default.
    pub fn f64_opt(name: &'static str, default: f64, doc: &'static str) -> ParamSpec {
        ParamSpec {
            name,
            ty: ParamType::F64,
            required: false,
            default: Some(ParamValue::F64(default)),
            range: ParamRange::Any,
            doc,
        }
    }

    /// Required string parameter constrained to `one_of`.
    pub fn str_req(
        name: &'static str,
        one_of: &'static [&'static str],
        doc: &'static str,
    ) -> ParamSpec {
        ParamSpec {
            name,
            ty: ParamType::Str,
            required: true,
            default: None,
            range: ParamRange::OneOf(one_of),
            doc,
        }
    }

    /// Optional string parameter constrained to `one_of`.
    pub fn str_opt(
        name: &'static str,
        one_of: &'static [&'static str],
        doc: &'static str,
    ) -> ParamSpec {
        ParamSpec {
            name,
            ty: ParamType::Str,
            required: false,
            default: None,
            range: ParamRange::OneOf(one_of),
            doc,
        }
    }

    /// Attach a value range.
    pub fn with_range(mut self, range: ParamRange) -> ParamSpec {
        self.range = range;
        self
    }
}

/// One declared distributed output.
#[derive(Debug, Clone)]
pub struct OutputSpec {
    pub name: &'static str,
    pub doc: &'static str,
}

impl OutputSpec {
    pub fn new(name: &'static str, doc: &'static str) -> OutputSpec {
        OutputSpec { name, doc }
    }
}

/// Declarative shape/layout constraint over the resolved input metas.
/// Rules referencing a matrix param that is absent (only possible for
/// optional matrix params) are skipped.
#[derive(Debug, Clone, Copy)]
pub enum ShapeRule {
    /// `a.cols == b.rows` (GEMM compatibility).
    ColsEqRows(&'static str, &'static str),
    /// Same (rows, cols) on both.
    SameShape(&'static str, &'static str),
    /// Identical layout descriptor (kind + owners).
    SameLayout(&'static str, &'static str),
    /// `m.cols == n` exactly.
    ColsExactly(&'static str, u64),
    /// `a.rows == b.rows`.
    RowsMatch(&'static str, &'static str),
    /// Input must be RowBlock-distributed.
    RowBlock(&'static str),
    /// Input rows must be *partitioned* across owners (RowBlock or
    /// RowCyclic) — a `Replicated` input would make every
    /// partial-sum-then-all-reduce routine overcount by a factor of p.
    RowDistributed(&'static str),
    /// i64 param must satisfy `1 <= p <= min(m.rows, m.cols)`.
    ParamLeMinDim(&'static str, &'static str),
}

fn find<'a>(
    inputs: &'a [(&'static str, MatrixMeta)],
    name: &str,
) -> Option<&'a MatrixMeta> {
    inputs.iter().find(|(n, _)| *n == name).map(|(_, m)| m)
}

impl ShapeRule {
    fn check(
        &self,
        routine: &str,
        params: &Params,
        inputs: &[(&'static str, MatrixMeta)],
    ) -> Result<()> {
        let shape_err = |msg: String| Err(Error::Shape(format!("routine {routine}: {msg}")));
        match *self {
            ShapeRule::ColsEqRows(a, b) => match (find(inputs, a), find(inputs, b)) {
                (Some(ma), Some(mb)) if ma.cols != mb.rows => shape_err(format!(
                    "{a} is {}x{} but {b} is {}x{} ({a}.cols must equal {b}.rows)",
                    ma.rows, ma.cols, mb.rows, mb.cols
                )),
                _ => Ok(()),
            },
            ShapeRule::SameShape(a, b) => match (find(inputs, a), find(inputs, b)) {
                (Some(ma), Some(mb)) if (ma.rows, ma.cols) != (mb.rows, mb.cols) => {
                    shape_err(format!(
                        "{a} is {}x{} but {b} is {}x{} (shapes must match)",
                        ma.rows, ma.cols, mb.rows, mb.cols
                    ))
                }
                _ => Ok(()),
            },
            ShapeRule::SameLayout(a, b) => match (find(inputs, a), find(inputs, b)) {
                (Some(ma), Some(mb)) if ma.layout != mb.layout => {
                    shape_err(format!("{a} and {b} must share one layout"))
                }
                _ => Ok(()),
            },
            ShapeRule::ColsExactly(a, n) => match find(inputs, a) {
                Some(ma) if ma.cols != n => {
                    shape_err(format!("{a} must have exactly {n} column(s), has {}", ma.cols))
                }
                _ => Ok(()),
            },
            ShapeRule::RowsMatch(a, b) => match (find(inputs, a), find(inputs, b)) {
                (Some(ma), Some(mb)) if ma.rows != mb.rows => shape_err(format!(
                    "{a} has {} rows but {b} has {} (row counts must match)",
                    ma.rows, mb.rows
                )),
                _ => Ok(()),
            },
            ShapeRule::RowBlock(a) => match find(inputs, a) {
                Some(ma) if ma.layout.kind != crate::protocol::LayoutKind::RowBlock => {
                    shape_err(format!("{a} must be RowBlock-distributed (redistribute first)"))
                }
                _ => Ok(()),
            },
            ShapeRule::RowDistributed(a) => match find(inputs, a) {
                Some(ma) if ma.layout.kind == crate::protocol::LayoutKind::Replicated => {
                    shape_err(format!(
                        "{a} is Replicated; this routine needs a row-partitioned input"
                    ))
                }
                _ => Ok(()),
            },
            ShapeRule::ParamLeMinDim(p, a) => {
                let (Some(ma), Some((_, v))) =
                    (find(inputs, a), params.iter().find(|(k, _)| k == p))
                else {
                    return Ok(());
                };
                let x = v.as_i64()?;
                let cap = ma.rows.min(ma.cols);
                if x < 1 || x as u64 > cap {
                    return shape_err(format!(
                        "{p}={x} out of range for {} x {} {a} (must be in 1..={cap})",
                        ma.rows, ma.cols
                    ));
                }
                Ok(())
            }
        }
    }
}

/// Full typed specification of one routine.
#[derive(Clone)]
pub struct RoutineSpec {
    pub name: &'static str,
    pub summary: &'static str,
    pub params: Vec<ParamSpec>,
    pub outputs: Vec<OutputSpec>,
    pub shape_rules: Vec<ShapeRule>,
    pub cost: CostFn,
}

impl std::fmt::Debug for RoutineSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RoutineSpec")
            .field("name", &self.name)
            .field("params", &self.params.len())
            .field("outputs", &self.outputs.len())
            .finish()
    }
}

impl RoutineSpec {
    /// Spec with no params/outputs/rules and zero cost — extend with the
    /// struct-update syntax.
    pub fn new(name: &'static str, summary: &'static str) -> RoutineSpec {
        RoutineSpec {
            name,
            summary,
            params: vec![],
            outputs: vec![],
            shape_rules: vec![],
            cost: zero_cost,
        }
    }

    /// Validate `params` against this spec, resolving matrix handles
    /// through `lookup`. Returns the resolved `(param_name, meta)` inputs
    /// in spec order. Checks, in order: unknown/duplicate names, required
    /// presence, value types, value ranges, handle resolution, then the
    /// shape rules.
    pub fn validate(
        &self,
        params: &Params,
        mut lookup: impl FnMut(u64) -> Option<MatrixMeta>,
    ) -> Result<Vec<(&'static str, MatrixMeta)>> {
        for (i, (name, _)) in params.iter().enumerate() {
            if !self.params.iter().any(|p| p.name == name) {
                let known: Vec<&str> = self.params.iter().map(|p| p.name).collect();
                return Err(Error::Ali(format!(
                    "routine {}: unknown parameter {name:?} (expected among {known:?})",
                    self.name
                )));
            }
            if params.iter().skip(i + 1).any(|(other, _)| other == name) {
                return Err(Error::Ali(format!(
                    "routine {}: duplicate parameter {name:?}",
                    self.name
                )));
            }
        }

        let mut inputs: Vec<(&'static str, MatrixMeta)> = Vec::new();
        for spec in &self.params {
            let found = params.iter().find(|(k, _)| k == spec.name);
            let Some((_, value)) = found else {
                if spec.required {
                    return Err(Error::Ali(format!(
                        "routine {}: missing parameter {:?} (required, {})",
                        self.name,
                        spec.name,
                        spec.ty.name()
                    )));
                }
                continue;
            };
            let ctx = |e: Error| {
                Error::Ali(format!("routine {}: parameter {:?}: {e}", self.name, spec.name))
            };
            match spec.ty {
                ParamType::I64 => {
                    let x = value.as_i64().map_err(ctx)?;
                    if let ParamRange::I64 { min, max } = spec.range {
                        if x < min || x > max {
                            return Err(Error::Ali(format!(
                                "routine {}: parameter {:?} = {x} out of range [{min}, {max}]",
                                self.name, spec.name
                            )));
                        }
                    }
                }
                ParamType::F64 => {
                    let x = value.as_f64().map_err(ctx)?;
                    if let ParamRange::F64 { min, max } = spec.range {
                        if !(x >= min && x <= max) {
                            return Err(Error::Ali(format!(
                                "routine {}: parameter {:?} = {x} out of range [{min}, {max}]",
                                self.name, spec.name
                            )));
                        }
                    }
                }
                ParamType::Bool => {
                    if !matches!(value, ParamValue::Bool(_)) {
                        return Err(ctx(Error::Ali(format!("expected bool, got {value:?}"))));
                    }
                }
                ParamType::Str => {
                    let s = value.as_str().map_err(ctx)?;
                    match spec.range {
                        ParamRange::OneOf(choices) => {
                            if !choices.contains(&s) {
                                return Err(Error::Ali(format!(
                                    "routine {}: parameter {:?} = {s:?} not among {choices:?}",
                                    self.name, spec.name
                                )));
                            }
                        }
                        ParamRange::Grid => {
                            crate::elemental::GridSpec::parse(s).map_err(|e| {
                                Error::Ali(format!(
                                    "routine {}: parameter {:?}: {e}",
                                    self.name, spec.name
                                ))
                            })?;
                        }
                        _ => {}
                    }
                }
                ParamType::Matrix => {
                    let h = value.as_matrix().map_err(ctx)?;
                    let meta = lookup(h).ok_or_else(|| {
                        Error::Server(format!(
                            "routine {}: parameter {:?} references unknown matrix handle {h}",
                            self.name, spec.name
                        ))
                    })?;
                    inputs.push((spec.name, meta));
                }
            }
        }

        for rule in &self.shape_rules {
            rule.check(self.name, params, &inputs)?;
        }
        Ok(inputs)
    }

    /// Evaluate the cost function over resolved inputs.
    pub fn cost(&self, params: &Params, inputs: &[(&'static str, MatrixMeta)]) -> CostEstimate {
        let refs: Vec<(&str, &MatrixMeta)> = inputs.iter().map(|(n, m)| (*n, m)).collect();
        (self.cost)(params, &refs)
    }

    /// The serializable subset for `DescribeRoutines`.
    pub fn descriptor(&self) -> RoutineDescriptor {
        RoutineDescriptor {
            name: self.name.to_string(),
            summary: self.summary.to_string(),
            params: self
                .params
                .iter()
                .map(|p| ParamDescriptor {
                    name: p.name.to_string(),
                    ty: p.ty,
                    required: p.required,
                    default: p.default.clone(),
                    doc: p.doc.to_string(),
                })
                .collect(),
            outputs: self.outputs.iter().map(|o| o.name.to_string()).collect(),
        }
    }
}

/// Meta of the input named `name` among resolved inputs (routine bodies
/// use this after `validate`).
pub fn input_meta<'a>(
    inputs: &'a [(&'static str, MatrixMeta)],
    name: &str,
) -> Result<&'a MatrixMeta> {
    find(inputs, name)
        .ok_or_else(|| Error::Ali(format!("no resolved input matrix named {name:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ali::params::ParamsBuilder;
    use crate::protocol::{LayoutDesc, LayoutKind};

    fn meta(h: u64, rows: u64, cols: u64) -> MatrixMeta {
        MatrixMeta {
            handle: h,
            rows,
            cols,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: vec![0, 1] },
        }
    }

    fn gemm_like() -> RoutineSpec {
        RoutineSpec {
            params: vec![
                ParamSpec::matrix("A", "left"),
                ParamSpec::matrix("B", "right"),
                ParamSpec::f64_opt("alpha", 1.0, "scale"),
                ParamSpec::str_opt("algo", &["ring", "allgather"], "algorithm"),
                ParamSpec::i64_opt("panel_rows", 0, "sub-panel rows")
                    .with_range(ParamRange::I64 { min: 0, max: i64::MAX }),
                ParamSpec {
                    name: "grid",
                    ty: ParamType::Str,
                    required: false,
                    default: None,
                    range: ParamRange::Grid,
                    doc: "process grid",
                },
            ],
            outputs: vec![OutputSpec::new("C", "product")],
            shape_rules: vec![ShapeRule::ColsEqRows("A", "B"), ShapeRule::RowBlock("A")],
            ..RoutineSpec::new("gemm", "C = A * B")
        }
    }

    #[test]
    fn accepts_valid_params_and_resolves_inputs() {
        let spec = gemm_like();
        let p = ParamsBuilder::new().matrix("A", 1).matrix("B", 2).f64("alpha", 2.0).build();
        let lookup = |h: u64| match h {
            1 => Some(meta(1, 10, 4)),
            2 => Some(meta(2, 4, 3)),
            _ => None,
        };
        let inputs = spec.validate(&p, lookup).unwrap();
        assert_eq!(inputs.len(), 2);
        assert_eq!(input_meta(&inputs, "B").unwrap().cols, 3);
    }

    #[test]
    fn rejects_unknown_missing_mistyped_and_out_of_range() {
        let spec = gemm_like();
        let lookup = |h: u64| Some(meta(h, 4, 4));

        let p = ParamsBuilder::new().matrix("A", 1).matrix("B", 2).i64("bogus", 1).build();
        assert!(spec.validate(&p, lookup).unwrap_err().to_string().contains("unknown parameter"));

        let p = ParamsBuilder::new().matrix("A", 1).build();
        assert!(spec.validate(&p, lookup).unwrap_err().to_string().contains("missing parameter"));

        let p = ParamsBuilder::new().matrix("A", 1).str("B", "oops").build();
        assert!(spec.validate(&p, lookup).unwrap_err().to_string().contains("parameter \"B\""));

        let p = ParamsBuilder::new()
            .matrix("A", 1)
            .matrix("B", 2)
            .i64("panel_rows", -3)
            .build();
        assert!(spec.validate(&p, lookup).unwrap_err().to_string().contains("out of range"));

        let p =
            ParamsBuilder::new().matrix("A", 1).matrix("B", 2).str("algo", "summa3d").build();
        assert!(spec.validate(&p, lookup).unwrap_err().to_string().contains("not among"));

        let p = ParamsBuilder::new().matrix("A", 1).matrix("B", 2).matrix("A", 1).build();
        assert!(spec.validate(&p, lookup).unwrap_err().to_string().contains("duplicate"));

        // grid specs validate spelling pre-admission
        let p = ParamsBuilder::new().matrix("A", 1).matrix("B", 2).str("grid", "2x0").build();
        assert!(spec.validate(&p, lookup).unwrap_err().to_string().contains("grid"));
        for good in ["auto", "2x2", "1x8"] {
            let p = ParamsBuilder::new().matrix("A", 1).matrix("B", 2).str("grid", good).build();
            assert!(spec.validate(&p, lookup).is_ok(), "grid={good}");
        }
    }

    #[test]
    fn shape_rules_catch_mismatches() {
        let spec = gemm_like();
        let p = ParamsBuilder::new().matrix("A", 1).matrix("B", 2).build();
        // A is 10x4, B is 5x3: cols != rows
        let lookup = |h: u64| match h {
            1 => Some(meta(1, 10, 4)),
            2 => Some(meta(2, 5, 3)),
            _ => None,
        };
        let err = spec.validate(&p, lookup).unwrap_err();
        assert!(err.to_string().contains("must equal"), "{err}");

        // unknown handle surfaces as a Server error
        let err = spec.validate(&p, |_| None).unwrap_err();
        assert!(err.to_string().contains("unknown matrix handle"), "{err}");
    }

    #[test]
    fn param_le_min_dim() {
        let spec = RoutineSpec {
            params: vec![ParamSpec::matrix("A", "in"), ParamSpec::i64_req("k", "rank")],
            shape_rules: vec![ShapeRule::ParamLeMinDim("k", "A")],
            ..RoutineSpec::new("tsvd", "svd")
        };
        let lookup = |h: u64| Some(meta(h, 8, 5));
        let ok = ParamsBuilder::new().matrix("A", 1).i64("k", 5).build();
        assert!(spec.validate(&ok, lookup).is_ok());
        for bad_k in [0i64, 6, -2] {
            let bad = ParamsBuilder::new().matrix("A", 1).i64("k", bad_k).build();
            assert!(spec.validate(&bad, lookup).is_err(), "k={bad_k}");
        }
    }

    #[test]
    fn descriptor_roundtrips_the_serializable_subset() {
        let d = gemm_like().descriptor();
        assert_eq!(d.name, "gemm");
        assert_eq!(d.params.len(), 6);
        assert_eq!(d.outputs, vec!["C".to_string()]);
        assert!(d.params[0].required);
        assert_eq!(d.params[2].default, Some(ParamValue::F64(1.0)));
        let mut w = crate::protocol::Writer::new();
        d.encode(&mut w);
        let bytes = w.into_bytes();
        let mut r = crate::protocol::Reader::new(&bytes);
        assert_eq!(RoutineDescriptor::decode(&mut r).unwrap(), d);
    }

    #[test]
    fn i64_coerces_into_f64_params() {
        let spec = RoutineSpec {
            params: vec![ParamSpec::f64_req("alpha", "scale")],
            ..RoutineSpec::new("scale", "scale")
        };
        let p = ParamsBuilder::new().i64("alpha", 3).build();
        assert!(spec.validate(&p, |_| None).is_ok());
    }
}
