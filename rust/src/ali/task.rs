//! Cooperative cancellation and progress reporting for routine
//! invocations — the recoverable-long-running-call surface the Alchemist
//! deployment papers ask of a production interface.
//!
//! A routine runs SPMD across the session's worker group, so a rank may
//! never abort on its *local* cancel flag alone: one rank returning early
//! while its peers enter the next collective would wedge the mesh. The
//! contract is therefore:
//!
//! * [`CancelToken`] is a cheap shared flag, set asynchronously (the
//!   driver relays a client `CancelJob` to every worker over the
//!   always-responsive data plane);
//! * routines only act on it at **collective boundaries**, after
//!   agreement: each rank contributes its local flag to a tiny all-reduce
//!   (`comm::collectives::allreduce_flag`, or one piggybacked on an
//!   existing reduction) so every rank aborts at the same iteration or
//!   none does.
//!
//! [`StatusBoard`] is the per-worker rendezvous between the control loop
//! (which installs a token per `RunRoutine`) and the data-plane threads
//! (which deliver cancels and serve progress queries keyed by the
//! driver's `job_token`, so a stale cancel can never hit a later job).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::telemetry::TelemetrySink;

/// Shared cancel flag, checked cooperatively at collective boundaries.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation. Idempotent; never un-set.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// Local view of the flag. SPMD routines must not abort on this
    /// alone — agree via `collectives::allreduce_flag` first.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// The live `(phase, progress)` channel from a running routine back to
/// the driver's job table. Cloneable; reporting through a disabled sink
/// (tests, direct library calls) is a no-op.
#[derive(Clone, Default)]
pub struct ProgressSink {
    board: Option<Arc<StatusBoard>>,
    token: u64,
    spans: Option<Arc<TelemetrySink>>,
}

impl ProgressSink {
    /// Sink wired to a worker's status board under `token`.
    pub fn new(board: Arc<StatusBoard>, token: u64) -> ProgressSink {
        ProgressSink { board: Some(board), token, spans: None }
    }

    /// Also drop an instant span per report into `spans` (trace id =
    /// `token`), so phase transitions show up on the job timeline.
    pub fn with_spans(mut self, spans: Arc<TelemetrySink>) -> ProgressSink {
        self.spans = Some(spans);
        self
    }

    /// No-op sink for contexts without a driver watching (tests, local
    /// harnesses).
    pub fn disabled() -> ProgressSink {
        ProgressSink::default()
    }

    /// Publish the routine's current phase and completed fraction
    /// (`frac` is clamped to `[0, 1]`). Rank 0's reports are what
    /// `PollJob` surfaces; other ranks' reports are cheap and harmless.
    pub fn report(&self, phase: &str, frac: f64) {
        if let Some(board) = &self.board {
            board.report(self.token, phase, frac.clamp(0.0, 1.0));
        }
        if let Some(spans) = &self.spans {
            spans.mark(self.token, &format!("progress:{phase}"));
        }
    }
}

impl std::fmt::Debug for ProgressSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ProgressSink")
            .field("enabled", &self.board.is_some())
            .field("token", &self.token)
            .finish()
    }
}

/// State of the routine currently occupying a worker.
struct Active {
    token: u64,
    cancel: CancelToken,
    phase: String,
    frac: f64,
}

/// Cancels remembered for routines that have not *started* here yet —
/// covers the race where the driver's cancel frame (data plane) overtakes
/// the `RunRoutine` command (control plane). Bounded ring; tokens are
/// driver-unique so a stale entry can only ever match its own job.
const PENDING_CANCEL_CAP: usize = 64;

#[derive(Default)]
struct BoardInner {
    active: Option<Active>,
    pending_cancels: std::collections::VecDeque<u64>,
}

/// Per-worker rendezvous for out-of-band cancel/progress traffic. One
/// routine runs at a time per worker (sessions own disjoint workers and
/// serialize their jobs), so a single active slot suffices.
#[derive(Default)]
pub struct StatusBoard {
    inner: Mutex<BoardInner>,
}

impl StatusBoard {
    pub fn new() -> StatusBoard {
        StatusBoard::default()
    }

    /// Install a fresh token for the routine invoked under `token`,
    /// displacing any stale entry. Returns the token to thread into the
    /// routine's ctx — pre-cancelled if this token's cancel already
    /// arrived (the overtaking-frame race).
    pub fn begin(&self, token: u64) -> CancelToken {
        let cancel = CancelToken::new();
        let mut inner = self.inner.lock().unwrap();
        if inner.pending_cancels.iter().any(|&t| t == token) {
            inner.pending_cancels.retain(|&t| t != token);
            cancel.cancel();
        }
        inner.active = Some(Active {
            token,
            cancel: cancel.clone(),
            phase: String::new(),
            frac: 0.0,
        });
        cancel
    }

    /// Clear the slot once the routine returns (matched by token so an
    /// out-of-order call cannot clear a newer entry).
    pub fn finish(&self, token: u64) {
        let mut inner = self.inner.lock().unwrap();
        if inner.active.as_ref().map(|a| a.token) == Some(token) {
            inner.active = None;
        }
    }

    /// Deliver a cancel for `token`. True when a matching routine was
    /// active; otherwise the token is remembered so a `begin` that is
    /// still in flight starts pre-cancelled.
    pub fn cancel(&self, token: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let matched = match inner.active.as_ref() {
            Some(a) if a.token == token => {
                a.cancel.cancel();
                true
            }
            _ => false,
        };
        if !matched && !inner.pending_cancels.iter().any(|&t| t == token) {
            inner.pending_cancels.push_back(token);
            while inner.pending_cancels.len() > PENDING_CANCEL_CAP {
                inner.pending_cancels.pop_front();
            }
        }
        matched
    }

    /// Record a progress report from the routine running under `token`.
    pub fn report(&self, token: u64, phase: &str, frac: f64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(a) = inner.active.as_mut() {
            if a.token == token {
                a.phase.clear();
                a.phase.push_str(phase);
                a.frac = frac;
            }
        }
    }

    /// Latest `(phase, frac)` reported under `token`, if it is the
    /// active routine and has reported at least once.
    pub fn progress(&self, token: u64) -> Option<(String, f64)> {
        let inner = self.inner.lock().unwrap();
        match inner.active.as_ref() {
            Some(a) if a.token == token && !a.phase.is_empty() => {
                Some((a.phase.clone(), a.frac))
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_token_flags() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        let t2 = t.clone();
        t2.cancel();
        assert!(t.is_cancelled());
        t.cancel(); // idempotent
        assert!(t.is_cancelled());
    }

    #[test]
    fn early_cancel_is_remembered_until_begin() {
        let b = StatusBoard::new();
        // Cancel arrives before the RunRoutine command: remembered...
        assert!(!b.cancel(5));
        // ...so the routine starts pre-cancelled.
        assert!(b.begin(5).is_cancelled());
        b.finish(5);
        // The pending entry was consumed: a re-run of token 5 (cannot
        // happen in practice — tokens are unique) starts clean.
        assert!(!b.begin(5).is_cancelled());
    }

    #[test]
    fn board_token_matching() {
        let b = StatusBoard::new();
        // Nothing active: progress misses.
        assert!(b.progress(1).is_none());

        let tok = b.begin(1);
        assert!(!tok.is_cancelled());
        // No report yet -> no progress.
        assert!(b.progress(1).is_none());
        b.report(1, "lanczos", 0.5);
        assert_eq!(b.progress(1).unwrap(), ("lanczos".to_string(), 0.5));
        // Wrong token: ignored.
        b.report(2, "other", 0.9);
        assert!(b.progress(2).is_none());
        assert!(!b.cancel(2));
        assert!(!tok.is_cancelled());
        // Matching cancel reaches the routine's token.
        assert!(b.cancel(1));
        assert!(tok.is_cancelled());

        // finish clears only a matching entry.
        b.finish(2);
        assert!(b.progress(1).is_some());
        b.finish(1);
        assert!(b.progress(1).is_none());
        assert!(!b.cancel(1));
    }

    #[test]
    fn sink_clamps_and_disabled_is_noop() {
        let board = Arc::new(StatusBoard::new());
        board.begin(7);
        let sink = ProgressSink::new(board.clone(), 7);
        sink.report("x", 2.5);
        assert_eq!(board.progress(7).unwrap().1, 1.0);
        ProgressSink::disabled().report("y", 0.5); // must not panic
    }
}
