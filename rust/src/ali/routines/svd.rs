//! `truncated_svd(A, k) -> U, S, V` — ARPACK-style thick-restart Lanczos
//! on the Gram operator (the paper's Figs 3/4) — and `condest(A)`, the
//! paper's §3.3 example routine built on the same operator.

use crate::ali::routines::{rank_slot, replicated_ok, slice_replicated};
use crate::ali::spec::{
    CostEstimate, OutputSpec, ParamRange, ParamSpec, RoutineSpec, ShapeRule,
};
use crate::ali::task::{CancelToken, ProgressSink};
use crate::ali::{params, Routine, RoutineCtx, RoutineOutput};
use crate::arpack::{lanczos_topk, LanczosOptions, SymOp};
use crate::comm::{collectives, Mesh};
use crate::elemental::dist_gemm::dist_gram_matvec;
use crate::linalg::DenseMatrix;
use crate::protocol::{LayoutDesc, LayoutKind, MatrixMeta, ParamValue, Params};
use crate::runtime::tiling::pjrt_gram_matvec;
use crate::{Error, Result};

/// Distributed Gram operator: w = Σ_ranks A_rᵀ(A_r v), one ring
/// all-reduce per application. Local halves go through the fused PJRT
/// artifacts with **device-resident cached panels** when available (the
/// panel is uploaded once; later iterations only ship v), else native
/// kernels. The panel is *borrowed* from the worker's store — the
/// operator never copies it (the old full-panel clone was one whole copy
/// of A on the Fig 3/4 hot path).
///
/// Each application ends with a scalar cancel-agreement all-reduce
/// (`allreduce_flag`), so a client `CancelJob` takes effect within one
/// Lanczos iteration of every rank's token being set — and every rank
/// aborts at the same iteration (see `ali::task`).
pub(crate) struct DistGramOp<'a> {
    mesh: &'a mut Mesh,
    local: &'a DenseMatrix,
    runtime: Option<&'static crate::runtime::PjrtRuntime>,
    cached: Option<crate::runtime::tiling::CachedGramPanel>,
    cancel: CancelToken,
    progress: ProgressSink,
    pub applications: usize,
}

impl<'a> DistGramOp<'a> {
    /// `handle` keys the device-buffer cache (worker `FreeMatrix`
    /// invalidates it). The cache base also folds in the session rank:
    /// in this testbed all in-process workers share one PJRT runtime, so
    /// two ranks' panels of the same handle must not collide (separate
    /// worker *processes* would each have their own runtime).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        mesh: &'a mut Mesh,
        local: &'a DenseMatrix,
        runtime: Option<&'static crate::runtime::PjrtRuntime>,
        handle: u64,
        use_pjrt: bool,
        cancel: CancelToken,
        progress: ProgressSink,
    ) -> Result<DistGramOp<'a>> {
        let base = handle * 256 + mesh.rank() as u64;
        let runtime = if use_pjrt { runtime } else { None };
        let cached = match runtime {
            Some(rt) => crate::runtime::tiling::CachedGramPanel::new(rt, base, local)?,
            None => None,
        };
        Ok(DistGramOp { mesh, local, runtime, cached, cancel, progress, applications: 0 })
    }
}

impl SymOp for DistGramOp<'_> {
    fn dim(&self) -> usize {
        self.local.cols()
    }

    fn apply(&mut self, v: &[f64]) -> Result<Vec<f64>> {
        self.applications += 1;
        let local = self.local;
        let rt = self.runtime;
        let cached = self.cached.as_ref();
        let w = dist_gram_matvec(self.mesh, v, move |x| match (cached, rt) {
            (Some(panel), Some(rt)) => panel.apply(rt, x),
            (None, Some(rt)) => pjrt_gram_matvec(rt, local, x),
            (_, None) => {
                let t = local.matvec(x)?;
                local.matvec_t(&t)
            }
        })?;
        // Cancel agreement at the collective boundary. Kept as a separate
        // scalar all-reduce (not piggybacked on the Gram reduction) so
        // the main-path summation order — and therefore the routine's
        // output bits — are unchanged from the pre-engine code.
        if collectives::allreduce_flag(self.mesh, self.cancel.is_cancelled())? {
            return Err(Error::Cancelled(format!(
                "cancelled after {} Gram applications",
                self.applications
            )));
        }
        // Lanczos has no fixed iteration count; report a monotone
        // asymptotic fraction so `PollJob` sees movement.
        let a = self.applications as f64;
        self.progress.report("lanczos", a / (a + 32.0));
        Ok(w)
    }
}

fn tsvd_cost(p: &Params, inputs: &[(&str, &MatrixMeta)]) -> CostEstimate {
    let k = p
        .iter()
        .find(|(name, _)| name == "k")
        .and_then(|(_, v)| v.as_i64().ok())
        .unwrap_or(1)
        .max(1) as f64;
    match inputs.iter().find(|(name, _)| *name == "A") {
        Some((_, a)) => {
            let (m, n) = (a.rows as f64, a.cols as f64);
            CostEstimate { flops: 4.0 * m * n * (2.0 * k + 30.0), bytes: 8.0 * m * n }
        }
        None => CostEstimate::default(),
    }
}

pub struct TruncatedSvd;

impl TruncatedSvd {
    pub fn spec() -> RoutineSpec {
        RoutineSpec {
            params: vec![
                ParamSpec::matrix("A", "input matrix (m x n)"),
                ParamSpec::i64_req("k", "number of singular triplets"),
                ParamSpec::f64_opt("tol", 1e-10, "Lanczos residual tolerance")
                    .with_range(ParamRange::F64 { min: 0.0, max: f64::INFINITY }),
            ],
            outputs: vec![
                OutputSpec::new("U", "left singular vectors (m x k, layout of A)"),
                OutputSpec::new("S", "singular values (k x 1, replicated)"),
                OutputSpec::new("V", "right singular vectors (n x k, replicated)"),
            ],
            shape_rules: vec![ShapeRule::RowDistributed("A"), ShapeRule::ParamLeMinDim("k", "A")],
            cost: tsvd_cost,
            ..RoutineSpec::new("truncated_svd", "rank-k truncated SVD (thick-restart Lanczos)")
        }
    }
}

static TSVD_SPEC: std::sync::OnceLock<RoutineSpec> = std::sync::OnceLock::new();

impl Routine for TruncatedSvd {
    fn spec(&self) -> &RoutineSpec {
        TSVD_SPEC.get_or_init(TruncatedSvd::spec)
    }

    fn run(&self, p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        let ha = params::get_matrix(p, "A")?;
        let k = params::get_i64(p, "k")? as usize;
        let tol = params::get_f64_or(p, "tol", 1e-10)?;
        let hu = ctx.output_handle(0)?;
        let hs = ctx.output_handle(1)?;
        let hv = ctx.output_handle(2)?;

        let a_meta = ctx.store.get(ha)?.meta.clone();
        let (m, n) = (a_meta.rows, a_meta.cols);
        if k == 0 || k as u64 > n.min(m) {
            return Err(Error::Numerical(format!(
                "truncated_svd: k={k} out of range for {m}x{n}"
            )));
        }

        // SPMD Lanczos: every rank runs the identical iteration; the only
        // cross-rank ops are the all-reduces inside the Gram operator,
        // which are deterministic, so all ranks hold identical basis/Ritz
        // state. The operator reads the stored panel in place (disjoint
        // borrows: ctx.store immutably, ctx.mesh mutably).
        let result = {
            let a = ctx.store.get(ha)?;
            let mut op = DistGramOp::new(
                ctx.mesh,
                a.local(),
                ctx.runtime,
                ha,
                ctx.svd_pjrt,
                ctx.cancel.clone(),
                ctx.progress.clone(),
            )?;
            lanczos_topk(&mut op, k, &LanczosOptions { tol, ..Default::default() })?
        };
        ctx.progress.report("factor", 0.9);

        let mut sigma = Vec::with_capacity(k);
        let mut v_full = DenseMatrix::zeros(n as usize, k);
        for (j, (theta, vec)) in result.eigenvalues.iter().zip(&result.eigenvectors).enumerate()
        {
            sigma.push(theta.max(0.0).sqrt());
            for i in 0..n as usize {
                v_full.set(i, j, vec[i]);
            }
        }

        // U_local = A_local V Σ⁻¹ (rank-deficient columns zeroed).
        let mut u_local = {
            let a = ctx.store.get(ha)?;
            ctx.backend.gemm(a.local(), &v_full)?
        };
        for j in 0..k {
            let s = sigma[j];
            let inv = if s > 1e-12 { 1.0 / s } else { 0.0 };
            for i in 0..u_local.rows() {
                let cur = u_local.get(i, j);
                u_local.set(i, j, cur * inv);
            }
        }

        let owners = ctx.owners.clone();
        let rank = ctx.mesh.rank() as u32;
        // S (k x 1) and V (n x k) are logically replicated on every rank.
        // v6+ sessions store them under the explicit Replicated layout so
        // client fetches read one owner; older sessions keep the legacy
        // RowBlock slicing (with its k < p zero-row owners).
        let small_kind = if replicated_ok(ctx.wire_version) {
            LayoutKind::Replicated
        } else {
            LayoutKind::RowBlock
        };
        let layout =
            |_rows: u64| LayoutDesc { kind: small_kind, owners: owners.clone() };

        // U: same row distribution as A.
        let u_meta =
            MatrixMeta { handle: hu, rows: m, cols: k as u64, layout: a_meta.layout.clone() };
        let u_slot = rank_slot(&a_meta, rank)?;
        let u_panel = crate::elemental::LocalPanel::from_local(u_meta.clone(), u_slot, u_local)?;

        let s_meta = MatrixMeta { handle: hs, rows: k as u64, cols: 1, layout: layout(k as u64) };
        let s_panel = slice_replicated(&s_meta, rank, |i, _| sigma[i as usize])?;
        let v_meta = MatrixMeta { handle: hv, rows: n, cols: k as u64, layout: layout(n) };
        let v_panel =
            slice_replicated(&v_meta, rank, |i, j| v_full.get(i as usize, j as usize))?;

        let metas = vec![u_meta, s_meta, v_meta];
        ctx.store.insert(u_panel)?;
        ctx.store.insert(s_panel)?;
        ctx.store.insert(v_panel)?;

        Ok(RoutineOutput {
            outputs: vec![
                ("matvecs".into(), ParamValue::I64(result.matvecs as i64)),
                ("restarts".into(), ParamValue::I64(result.restarts as i64)),
            ],
            new_matrices: metas,
        })
    }
}

fn condest_cost(p: &Params, inputs: &[(&str, &MatrixMeta)]) -> CostEstimate {
    let probes = p
        .iter()
        .find(|(name, _)| name == "probes")
        .and_then(|(_, v)| v.as_i64().ok())
        .unwrap_or(8)
        .max(1) as f64;
    match inputs.iter().find(|(name, _)| *name == "A") {
        Some((_, a)) => {
            let (m, n) = (a.rows as f64, a.cols as f64);
            CostEstimate { flops: 4.0 * m * n * (4.0 * probes + 20.0), bytes: 8.0 * m * n }
        }
        None => CostEstimate::default(),
    }
}

pub struct CondEst;

impl CondEst {
    pub fn spec() -> RoutineSpec {
        RoutineSpec {
            params: vec![
                ParamSpec::matrix("A", "input matrix (m x n)"),
                ParamSpec::i64_opt("probes", 8, "Lanczos probes (clamped to [2, n])")
                    .with_range(ParamRange::I64 { min: 1, max: i64::MAX }),
            ],
            shape_rules: vec![ShapeRule::RowDistributed("A")],
            cost: condest_cost,
            ..RoutineSpec::new("condest", "2-norm condition-number estimate via the Gram operator")
        }
    }
}

static CONDEST_SPEC: std::sync::OnceLock<RoutineSpec> = std::sync::OnceLock::new();

impl Routine for CondEst {
    fn spec(&self) -> &RoutineSpec {
        CONDEST_SPEC.get_or_init(CondEst::spec)
    }

    fn run(&self, p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        let ha = params::get_matrix(p, "A")?;
        let probes = params::get_i64_or(p, "probes", 8)? as usize;
        let n = ctx.store.get(ha)?.meta.cols as usize;
        let k = probes.clamp(2.min(n), n);
        // Same in-place panel borrow as truncated_svd (no panel clone).
        let result = {
            let a = ctx.store.get(ha)?;
            let mut op = DistGramOp::new(
                ctx.mesh,
                a.local(),
                ctx.runtime,
                ha,
                ctx.svd_pjrt,
                ctx.cancel.clone(),
                ctx.progress.clone(),
            )?;
            let opts = LanczosOptions { max_basis: (4 * k + 20).min(n), ..Default::default() };
            lanczos_topk(&mut op, k, &opts)?
        };
        let smax = result.eigenvalues.first().copied().unwrap_or(0.0).max(0.0).sqrt();
        let smin = result.eigenvalues.last().copied().unwrap_or(0.0).max(0.0).sqrt();
        let cond = if smin <= 1e-300 { f64::INFINITY } else { smax / smin };
        Ok(RoutineOutput {
            outputs: vec![("condest".into(), ParamValue::F64(cond))],
            new_matrices: vec![],
        })
    }
}
