//! `gemm(A, B) -> C` — distributed GEMM (Table 1's workhorse).

use crate::ali::spec::{
    CostEstimate, OutputSpec, ParamRange, ParamSpec, RoutineSpec, ShapeRule,
};
use crate::ali::{params, Routine, RoutineCtx, RoutineOutput};
use crate::elemental::dist_gemm::{dist_gemm_with_cancel, DistGemmAlgo};
use crate::elemental::GridSpec;
use crate::protocol::{MatrixMeta, ParamType, Params};
use crate::{Error, Result};

fn cost(_p: &Params, inputs: &[(&str, &MatrixMeta)]) -> CostEstimate {
    let (mut m, mut k, mut n) = (0.0, 0.0, 0.0);
    for (name, meta) in inputs {
        match *name {
            "A" => {
                m = meta.rows as f64;
                k = meta.cols as f64;
            }
            "B" => n = meta.cols as f64,
            _ => {}
        }
    }
    CostEstimate { flops: 2.0 * m * k * n, bytes: 8.0 * (m * k + k * n + m * n) }
}

pub struct Gemm;

impl Gemm {
    pub fn spec() -> RoutineSpec {
        RoutineSpec {
            params: vec![
                ParamSpec::matrix("A", "left operand (m x k, RowBlock)"),
                ParamSpec::matrix("B", "right operand (k x n, RowBlock)"),
                ParamSpec::f64_opt("alpha", 1.0, "scale applied to the product"),
                ParamSpec::str_opt(
                    "algo",
                    &["ring", "allgather", "summa2d"],
                    "distributed algorithm override ([compute] default otherwise)",
                ),
                ParamSpec::i64_opt("panel_rows", 0, "sub-panel rows per shift (0 = whole)")
                    .with_range(ParamRange::I64 { min: 0, max: i64::MAX }),
                ParamSpec {
                    name: "grid",
                    ty: ParamType::Str,
                    required: false,
                    default: None,
                    range: ParamRange::Grid,
                    doc: "summa2d process grid: \"auto\" or \"RxC\" (must tile the worker group)",
                },
            ],
            outputs: vec![OutputSpec::new("C", "alpha * A * B, RowBlock like A")],
            shape_rules: vec![
                ShapeRule::RowBlock("A"),
                ShapeRule::RowBlock("B"),
                ShapeRule::ColsEqRows("A", "B"),
            ],
            cost,
            ..RoutineSpec::new("gemm", "distributed C = alpha * A * B")
        }
    }
}

static GEMM_SPEC: std::sync::OnceLock<RoutineSpec> = std::sync::OnceLock::new();

impl Routine for Gemm {
    fn spec(&self) -> &RoutineSpec {
        GEMM_SPEC.get_or_init(Gemm::spec)
    }

    fn run(&self, p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        let ha = params::get_matrix(p, "A")?;
        let hb = params::get_matrix(p, "B")?;
        let hc = ctx.output_handle(0)?;
        let alpha = params::get_f64_or(p, "alpha", 1.0)?;
        // Per-call overrides of the worker's `[compute]` defaults. SPMD-safe:
        // every rank receives the identical params frame.
        let mut opts = ctx.compute;
        if let Some(algo) = params::get_str_opt(p, "algo")? {
            opts.algo = DistGemmAlgo::parse(algo).map_err(|e| Error::Ali(e.to_string()))?;
        }
        let rows = params::get_i64_or(p, "panel_rows", opts.panel_rows as i64)?;
        if rows < 0 {
            return Err(Error::Ali("panel_rows must be >= 0".into()));
        }
        opts.panel_rows = rows as usize;
        if let Some(grid) = params::get_str_opt(p, "grid")? {
            opts.grid = GridSpec::parse(grid).map_err(|e| Error::Ali(e.to_string()))?;
        }
        ctx.progress.report("dist_gemm", 0.05);
        // The stored panels are read in place (disjoint-field borrows of
        // ctx: store immutably, mesh mutably) — no per-call panel copies.
        let mut c = {
            let a = ctx.store.get(ha)?;
            let b = ctx.store.get(hb)?;
            dist_gemm_with_cancel(ctx.mesh, a, b, hc, ctx.backend, &opts, Some(&ctx.cancel))?
        };
        if alpha != 1.0 {
            c.local_mut().scale(alpha);
        }
        ctx.progress.report("store_output", 0.95);
        let meta = c.meta.clone();
        ctx.store.insert(c)?;
        Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
    }
}
