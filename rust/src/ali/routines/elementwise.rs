//! Purely local elementwise routines: `scale` and `add`.

use crate::ali::spec::{CostEstimate, OutputSpec, ParamSpec, RoutineSpec, ShapeRule};
use crate::ali::{params, Routine, RoutineCtx, RoutineOutput};
use crate::elemental::LocalPanel;
use crate::protocol::{MatrixMeta, Params};
use crate::Result;

fn area(inputs: &[(&str, &MatrixMeta)], name: &str) -> f64 {
    inputs
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, m)| m.rows as f64 * m.cols as f64)
        .unwrap_or(0.0)
}

fn scale_cost(_p: &Params, inputs: &[(&str, &MatrixMeta)]) -> CostEstimate {
    let a = area(inputs, "A");
    CostEstimate { flops: a, bytes: 16.0 * a }
}

pub struct Scale;

impl Scale {
    pub fn spec() -> RoutineSpec {
        RoutineSpec {
            params: vec![
                ParamSpec::matrix("A", "input matrix"),
                ParamSpec::f64_req("alpha", "scale factor"),
            ],
            outputs: vec![OutputSpec::new("B", "alpha * A, layout of A")],
            cost: scale_cost,
            ..RoutineSpec::new("scale", "B = alpha * A (local, no communication)")
        }
    }
}

static SCALE_SPEC: std::sync::OnceLock<RoutineSpec> = std::sync::OnceLock::new();

impl Routine for Scale {
    fn spec(&self) -> &RoutineSpec {
        SCALE_SPEC.get_or_init(Scale::spec)
    }

    fn run(&self, p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        let ha = params::get_matrix(p, "A")?;
        let alpha = params::get_f64(p, "alpha")?;
        let hb = ctx.output_handle(0)?;
        let a = ctx.store.get(ha)?;
        let mut local = a.local().clone();
        local.scale(alpha);
        let meta = MatrixMeta { handle: hb, ..a.meta.clone() };
        let slot = a.slot;
        let panel = LocalPanel::from_local(meta.clone(), slot, local)?;
        ctx.store.insert(panel)?;
        Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
    }
}

fn add_cost(_p: &Params, inputs: &[(&str, &MatrixMeta)]) -> CostEstimate {
    let a = area(inputs, "A");
    CostEstimate { flops: 3.0 * a, bytes: 24.0 * a }
}

pub struct Add;

impl Add {
    pub fn spec() -> RoutineSpec {
        RoutineSpec {
            params: vec![
                ParamSpec::matrix("A", "left operand"),
                ParamSpec::matrix("B", "right operand (shape/layout of A)"),
                ParamSpec::f64_opt("alpha", 1.0, "scale on A"),
                ParamSpec::f64_opt("beta", 1.0, "scale on B"),
            ],
            outputs: vec![OutputSpec::new("C", "alpha * A + beta * B, layout of A")],
            shape_rules: vec![ShapeRule::SameShape("A", "B"), ShapeRule::SameLayout("A", "B")],
            cost: add_cost,
            ..RoutineSpec::new("add", "C = alpha * A + beta * B (local, no communication)")
        }
    }
}

static ADD_SPEC: std::sync::OnceLock<RoutineSpec> = std::sync::OnceLock::new();

impl Routine for Add {
    fn spec(&self) -> &RoutineSpec {
        ADD_SPEC.get_or_init(Add::spec)
    }

    fn run(&self, p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        // C = alpha A + beta B (same shape, same layout — purely local;
        // the spec's shape rules enforced the operand agreement).
        let ha = params::get_matrix(p, "A")?;
        let hb = params::get_matrix(p, "B")?;
        let alpha = params::get_f64_or(p, "alpha", 1.0)?;
        let beta = params::get_f64_or(p, "beta", 1.0)?;
        let hc = ctx.output_handle(0)?;
        let a = ctx.store.get(ha)?;
        let b = ctx.store.get(hb)?;
        if a.meta.rows != b.meta.rows
            || a.meta.cols != b.meta.cols
            || a.meta.layout != b.meta.layout
        {
            return Err(crate::Error::Shape("add: shape/layout mismatch".into()));
        }
        let mut local = a.local().clone();
        local.scale(alpha);
        for (dst, src) in local.data_mut().iter_mut().zip(b.local().data()) {
            *dst += beta * src;
        }
        let meta = MatrixMeta { handle: hc, ..a.meta.clone() };
        let slot = a.slot;
        let panel = LocalPanel::from_local(meta.clone(), slot, local)?;
        ctx.store.insert(panel)?;
        Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
    }
}
