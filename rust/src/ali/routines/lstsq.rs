//! `lstsq(A, y) -> x` — tall-skinny least squares via distributed normal
//! equations + Cholesky (the regression workload the paper's intro
//! motivates).

use crate::ali::routines::slice_replicated;
use crate::ali::spec::{
    CostEstimate, OutputSpec, ParamRange, ParamSpec, RoutineSpec, ShapeRule,
};
use crate::ali::{params, Routine, RoutineCtx, RoutineOutput};
use crate::comm::collectives::{allreduce_sum, AllReduceAlgo};
use crate::linalg::DenseMatrix;
use crate::protocol::{LayoutDesc, LayoutKind, MatrixMeta, ParamValue, Params};
use crate::{Error, Result};

fn cost(_p: &Params, inputs: &[(&str, &MatrixMeta)]) -> CostEstimate {
    match inputs.iter().find(|(n, _)| *n == "A") {
        Some((_, a)) => {
            let (m, n) = (a.rows as f64, a.cols as f64);
            CostEstimate {
                flops: 2.0 * m * n * n + n * n * n / 3.0,
                bytes: 8.0 * (m * n + n * n),
            }
        }
        None => CostEstimate::default(),
    }
}

pub struct Lstsq;

impl Lstsq {
    pub fn spec() -> RoutineSpec {
        RoutineSpec {
            params: vec![
                ParamSpec::matrix("A", "design matrix (m x n)"),
                ParamSpec::matrix("y", "targets (m x 1, layout of A)"),
                ParamSpec::f64_opt("ridge", 0.0, "Tikhonov regularization added to G's diagonal")
                    .with_range(ParamRange::F64 { min: 0.0, max: f64::INFINITY }),
            ],
            outputs: vec![OutputSpec::new("x", "solution (n x 1)")],
            shape_rules: vec![
                ShapeRule::RowDistributed("A"),
                ShapeRule::RowsMatch("y", "A"),
                ShapeRule::ColsExactly("y", 1),
                ShapeRule::SameLayout("y", "A"),
            ],
            cost,
            ..RoutineSpec::new(
                "lstsq",
                "least-squares solve via distributed normal equations + Cholesky",
            )
        }
    }
}

static LSTSQ_SPEC: std::sync::OnceLock<RoutineSpec> = std::sync::OnceLock::new();

impl Routine for Lstsq {
    fn spec(&self) -> &RoutineSpec {
        LSTSQ_SPEC.get_or_init(Lstsq::spec)
    }

    fn run(&self, p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        // min_x ||A x - y||_2 via normal equations + Cholesky:
        //   G = AᵀA (all-reduced), b = Aᵀy (all-reduced), G x = b locally.
        let ha = params::get_matrix(p, "A")?;
        let hy = params::get_matrix(p, "y")?;
        let ridge = params::get_f64_or(p, "ridge", 0.0)?;
        let hx = ctx.output_handle(0)?;

        let (n, x, res) = {
            let a = ctx.store.get(ha)?;
            let y = ctx.store.get(hy)?;
            if y.meta.rows != a.meta.rows || y.meta.cols != 1 || y.meta.layout != a.meta.layout
            {
                return Err(Error::Shape("lstsq: y must be m x 1 with A's layout".into()));
            }
            let n = a.meta.cols as usize;
            let y_local: Vec<f64> = (0..y.local_rows()).map(|i| y.local().get(i, 0)).collect();

            let mut g = crate::linalg::gemm::gemm_tn(a.local(), a.local())?.into_vec();
            let mut b = a.local().matvec_t(&y_local)?;
            allreduce_sum(ctx.mesh, &mut g, AllReduceAlgo::Ring)?;
            allreduce_sum(ctx.mesh, &mut b, AllReduceAlgo::Ring)?;
            let mut g_full = DenseMatrix::from_vec(n, n, g)?;
            if ridge > 0.0 {
                for i in 0..n {
                    g_full.set(i, i, g_full.get(i, i) + ridge);
                }
            }
            let x = crate::linalg::cholesky::spd_solve(&g_full, &b)?;

            // residual norm: local ||A_loc x - y_loc||^2, all-reduced
            let ax = a.local().matvec(&x)?;
            let mut res = vec![ax
                .iter()
                .zip(&y_local)
                .map(|(p, q)| (p - q) * (p - q))
                .sum::<f64>()];
            allreduce_sum(ctx.mesh, &mut res, AllReduceAlgo::Ring)?;
            (n, x, res)
        };

        let meta = MatrixMeta {
            handle: hx,
            rows: n as u64,
            cols: 1,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: ctx.owners.clone() },
        };
        let rank = ctx.mesh.rank() as u32;
        let panel = slice_replicated(&meta, rank, |i, _| x[i as usize])?;
        ctx.store.insert(panel)?;
        Ok(RoutineOutput {
            outputs: vec![("residual".into(), ParamValue::F64(res[0].sqrt()))],
            new_matrices: vec![meta],
        })
    }
}
