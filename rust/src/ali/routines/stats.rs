//! Reduction-shaped routines: `fro_norm`, `gramian`, `col_stats`.

use crate::ali::routines::slice_replicated;
use crate::ali::spec::{CostEstimate, OutputSpec, ParamSpec, RoutineSpec, ShapeRule};
use crate::ali::{params, Routine, RoutineCtx, RoutineOutput};
use crate::comm::collectives::{allreduce_sum, AllReduceAlgo};
use crate::elemental::dist_gemm::dist_frobenius;
use crate::linalg::DenseMatrix;
use crate::protocol::{LayoutDesc, LayoutKind, MatrixMeta, ParamValue, Params};
use crate::Result;

fn area(inputs: &[(&str, &MatrixMeta)]) -> f64 {
    inputs
        .iter()
        .find(|(n, _)| *n == "A")
        .map(|(_, m)| m.rows as f64 * m.cols as f64)
        .unwrap_or(0.0)
}

fn linear_cost(_p: &Params, inputs: &[(&str, &MatrixMeta)]) -> CostEstimate {
    let a = area(inputs);
    CostEstimate { flops: 2.0 * a, bytes: 8.0 * a }
}

pub struct FroNorm;

impl FroNorm {
    pub fn spec() -> RoutineSpec {
        RoutineSpec {
            params: vec![ParamSpec::matrix("A", "input matrix")],
            shape_rules: vec![ShapeRule::RowDistributed("A")],
            cost: linear_cost,
            ..RoutineSpec::new("fro_norm", "distributed Frobenius norm (scalar output)")
        }
    }
}

static FRO_SPEC: std::sync::OnceLock<RoutineSpec> = std::sync::OnceLock::new();

impl Routine for FroNorm {
    fn spec(&self) -> &RoutineSpec {
        FRO_SPEC.get_or_init(FroNorm::spec)
    }

    fn run(&self, p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        let ha = params::get_matrix(p, "A")?;
        let norm = {
            let a = ctx.store.get(ha)?;
            dist_frobenius(ctx.mesh, a)?
        };
        Ok(RoutineOutput {
            outputs: vec![("fro_norm".into(), ParamValue::F64(norm))],
            new_matrices: vec![],
        })
    }
}

fn gramian_cost(_p: &Params, inputs: &[(&str, &MatrixMeta)]) -> CostEstimate {
    match inputs.iter().find(|(n, _)| *n == "A") {
        Some((_, a)) => {
            let (m, n) = (a.rows as f64, a.cols as f64);
            CostEstimate { flops: 2.0 * m * n * n, bytes: 8.0 * (m * n + n * n) }
        }
        None => CostEstimate::default(),
    }
}

pub struct Gramian;

impl Gramian {
    pub fn spec() -> RoutineSpec {
        RoutineSpec {
            params: vec![ParamSpec::matrix("A", "input matrix (m x n, modest n)")],
            outputs: vec![OutputSpec::new("G", "A^T A (n x n)")],
            shape_rules: vec![ShapeRule::RowDistributed("A")],
            cost: gramian_cost,
            ..RoutineSpec::new("gramian", "G = A^T A via local gemm_tn + all-reduce")
        }
    }
}

static GRAMIAN_SPEC: std::sync::OnceLock<RoutineSpec> = std::sync::OnceLock::new();

impl Routine for Gramian {
    fn spec(&self) -> &RoutineSpec {
        GRAMIAN_SPEC.get_or_init(Gramian::spec)
    }

    fn run(&self, p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        // G = AᵀA (n x n): local gemm_tn + all-reduce, stored RowBlock.
        // MLlib's computeGramianMatrix analogue — n must be modest.
        let ha = params::get_matrix(p, "A")?;
        let hg = ctx.output_handle(0)?;
        let (n, g_full) = {
            let a = ctx.store.get(ha)?;
            let n = a.meta.cols as usize;
            let mut g = crate::linalg::gemm::gemm_tn(a.local(), a.local())?.into_vec();
            allreduce_sum(ctx.mesh, &mut g, AllReduceAlgo::Ring)?;
            (n, DenseMatrix::from_vec(n, n, g)?)
        };
        let meta = MatrixMeta {
            handle: hg,
            rows: n as u64,
            cols: n as u64,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: ctx.owners.clone() },
        };
        let rank = ctx.mesh.rank() as u32;
        let panel = slice_replicated(&meta, rank, |i, j| g_full.get(i as usize, j as usize))?;
        ctx.store.insert(panel)?;
        Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
    }
}

pub struct ColStats;

impl ColStats {
    pub fn spec() -> RoutineSpec {
        RoutineSpec {
            params: vec![ParamSpec::matrix("A", "input matrix")],
            outputs: vec![OutputSpec::new("S", "n x 2 [mean, stddev] per column")],
            shape_rules: vec![ShapeRule::RowDistributed("A")],
            cost: linear_cost,
            ..RoutineSpec::new("col_stats", "column means and population stddevs")
        }
    }
}

static COLSTATS_SPEC: std::sync::OnceLock<RoutineSpec> = std::sync::OnceLock::new();

impl Routine for ColStats {
    fn spec(&self) -> &RoutineSpec {
        COLSTATS_SPEC.get_or_init(ColStats::spec)
    }

    fn run(&self, p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        // column means and (population) stddevs -> n x 2 matrix [mean, std]
        let ha = params::get_matrix(p, "A")?;
        let hs = ctx.output_handle(0)?;
        let (n, m, acc) = {
            let a = ctx.store.get(ha)?;
            let n = a.meta.cols as usize;
            let m = a.meta.rows as f64;
            let mut acc = vec![0.0; 2 * n]; // sums then sumsq
            for (_, row) in a.iter_rows() {
                for (j, &v) in row.iter().enumerate() {
                    acc[j] += v;
                    acc[n + j] += v * v;
                }
            }
            allreduce_sum(ctx.mesh, &mut acc, AllReduceAlgo::Ring)?;
            (n, m, acc)
        };
        let meta = MatrixMeta {
            handle: hs,
            rows: n as u64,
            cols: 2,
            layout: LayoutDesc { kind: LayoutKind::RowBlock, owners: ctx.owners.clone() },
        };
        let rank = ctx.mesh.rank() as u32;
        let panel = slice_replicated(&meta, rank, |i, j| {
            let mean = acc[i as usize] / m;
            if j == 0 {
                mean
            } else {
                (acc[n + i as usize] / m - mean * mean).max(0.0).sqrt()
            }
        })?;
        ctx.store.insert(panel)?;
        Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
    }
}
