//! The builtin library's routines, one module per routine family, each a
//! [`Routine`](crate::ali::Routine) with a typed
//! [`RoutineSpec`](crate::ali::spec::RoutineSpec) — the per-routine split
//! of the old string-matched `ElemLib::run` monolith.
//!
//! [`registry`] assembles the table; its registration order is the
//! introspection order (`DescribeRoutines`, the README routine table).

pub mod elementwise;
pub mod gemm;
pub mod layoutops;
pub mod lstsq;
pub mod stats;
pub mod svd;

use std::sync::Arc;

use crate::ali::registry::RoutineRegistry;
use crate::elemental::LocalPanel;
use crate::protocol::{MatrixMeta, ROUTINE_ENGINE_PROTOCOL_VERSION};
use crate::{Error, Result};

/// The full elemlib routine table, in its canonical order.
pub fn registry() -> RoutineRegistry {
    let mut reg = RoutineRegistry::new();
    for routine in [
        Arc::new(gemm::Gemm) as Arc<dyn crate::ali::Routine>,
        Arc::new(svd::TruncatedSvd),
        Arc::new(svd::CondEst),
        Arc::new(stats::FroNorm),
        Arc::new(elementwise::Scale),
        Arc::new(layoutops::Redistribute),
        Arc::new(layoutops::Transpose),
        Arc::new(elementwise::Add),
        Arc::new(stats::Gramian),
        Arc::new(stats::ColStats),
        Arc::new(lstsq::Lstsq),
    ] {
        reg.register(routine).expect("builtin routine table has no duplicates");
    }
    reg
}

/// True when the session's client can decode `Replicated` layouts;
/// pre-v6 sessions get the legacy RowBlock slicing of small outputs.
pub fn replicated_ok(wire_version: u16) -> bool {
    wire_version >= ROUTINE_ENGINE_PROTOCOL_VERSION
}

/// Slot of this rank in a matrix's owner list (rank order == slot order).
pub(crate) fn rank_slot(meta: &MatrixMeta, rank: u32) -> Result<u32> {
    if (rank as usize) < meta.layout.owners.len() {
        Ok(rank)
    } else {
        Err(Error::Server(format!("rank {rank} outside owner list of handle {}", meta.handle)))
    }
}

/// Build this rank's panel of a logically replicated matrix defined by a
/// closure over (global_row, col). With a `Replicated` layout the panel
/// holds every row; with the legacy RowBlock layout it holds the rank's
/// slice (the k < p edge then leaves some owners with zero rows — see
/// rust/README.md §Replicated outputs).
pub(crate) fn slice_replicated(
    meta: &MatrixMeta,
    rank: u32,
    f: impl Fn(u64, u64) -> f64,
) -> Result<LocalPanel> {
    let mut panel = LocalPanel::alloc(meta.clone(), rank)?;
    let layout = panel.layout();
    let rows: Vec<u64> = layout.rows_of_slot(rank).collect();
    let mut buf = vec![0.0; meta.cols as usize];
    for r in rows {
        for (c, slot) in buf.iter_mut().enumerate() {
            *slot = f(r, c as u64);
        }
        panel.set_row(r, &buf)?;
    }
    Ok(panel)
}
