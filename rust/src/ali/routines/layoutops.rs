//! Layout/shape movers: `redistribute` (row-block ⇄ row-cyclic) and
//! `transpose`.

use crate::ali::spec::{CostEstimate, OutputSpec, ParamSpec, RoutineSpec, ShapeRule};
use crate::ali::{params, Routine, RoutineCtx, RoutineOutput};
use crate::elemental::redistribute::redistribute;
use crate::protocol::{LayoutKind, MatrixMeta, Params};
use crate::{Error, Result};

fn bytes_cost(_p: &Params, inputs: &[(&str, &MatrixMeta)]) -> CostEstimate {
    let a = inputs
        .iter()
        .find(|(n, _)| *n == "A")
        .map(|(_, m)| m.rows as f64 * m.cols as f64)
        .unwrap_or(0.0);
    CostEstimate { flops: 0.0, bytes: 16.0 * a }
}

pub struct Redistribute;

impl Redistribute {
    pub fn spec() -> RoutineSpec {
        RoutineSpec {
            params: vec![
                ParamSpec::matrix("A", "input matrix"),
                ParamSpec::str_req(
                    "kind",
                    &["row_block", "row_cyclic"],
                    "target row distribution",
                ),
            ],
            outputs: vec![OutputSpec::new("B", "A re-laid-out under `kind`")],
            shape_rules: vec![ShapeRule::RowDistributed("A")],
            cost: bytes_cost,
            ..RoutineSpec::new("redistribute", "re-distribute rows across the worker group")
        }
    }
}

static REDIST_SPEC: std::sync::OnceLock<RoutineSpec> = std::sync::OnceLock::new();

impl Routine for Redistribute {
    fn spec(&self) -> &RoutineSpec {
        REDIST_SPEC.get_or_init(Redistribute::spec)
    }

    fn run(&self, p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        let ha = params::get_matrix(p, "A")?;
        let kind = match params::get_str(p, "kind")? {
            "row_block" => LayoutKind::RowBlock,
            "row_cyclic" => LayoutKind::RowCyclic,
            other => return Err(Error::Ali(format!("unknown layout kind {other:?}"))),
        };
        let hb = ctx.output_handle(0)?;
        let out = {
            let a = ctx.store.get(ha)?;
            redistribute(ctx.mesh, a, hb, kind)?
        };
        let meta = out.meta.clone();
        ctx.store.insert(out)?;
        Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
    }
}

pub struct Transpose;

impl Transpose {
    pub fn spec() -> RoutineSpec {
        RoutineSpec {
            params: vec![ParamSpec::matrix("A", "input matrix (RowBlock)")],
            outputs: vec![OutputSpec::new("B", "A transposed, RowBlock")],
            shape_rules: vec![ShapeRule::RowBlock("A")],
            cost: bytes_cost,
            ..RoutineSpec::new("transpose", "distributed B = A^T")
        }
    }
}

static TRANSPOSE_SPEC: std::sync::OnceLock<RoutineSpec> = std::sync::OnceLock::new();

impl Routine for Transpose {
    fn spec(&self) -> &RoutineSpec {
        TRANSPOSE_SPEC.get_or_init(Transpose::spec)
    }

    fn run(&self, p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        let ha = params::get_matrix(p, "A")?;
        let hb = ctx.output_handle(0)?;
        let out = {
            let a = ctx.store.get(ha)?;
            if a.meta.layout.kind != LayoutKind::RowBlock {
                return Err(Error::Shape("transpose requires RowBlock input".into()));
            }
            crate::elemental::transpose::dist_transpose(ctx.mesh, a, hb)?
        };
        let meta = out.meta.clone();
        ctx.store.insert(out)?;
        Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
    }
}
