//! Library registry: the server-side table of loaded ALIs plus the
//! process-wide factory table that stands in for `dlopen`, and the
//! per-library [`RoutineRegistry`] of typed routines.
//!
//! Paper §2.4: "Alchemist loads every ALI that is required by some Spark
//! application dynamically at runtime" — and skips the ones nobody asked
//! for. Factories reproduce that: registering a library instantiates it
//! on each worker the first time a session asks for it. The driver loads
//! the same library in-process, which is how it gets the routine specs it
//! validates submissions against before sched admission.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::ali::spec::RoutineSpec;
use crate::ali::{Library, Routine};
use crate::{Error, Result};

/// Ordered table of a library's typed routines. Registration order is
/// the introspection/report order (`DescribeRoutines`, the README table).
#[derive(Default)]
pub struct RoutineRegistry {
    routines: Vec<Arc<dyn Routine>>,
}

impl RoutineRegistry {
    pub fn new() -> RoutineRegistry {
        RoutineRegistry::default()
    }

    /// Add a routine; duplicate names are a registration bug.
    pub fn register(&mut self, routine: Arc<dyn Routine>) -> Result<()> {
        let name = routine.spec().name;
        if self.routines.iter().any(|r| r.spec().name == name) {
            return Err(Error::Ali(format!("routine {name:?} registered twice")));
        }
        self.routines.push(routine);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&Arc<dyn Routine>> {
        self.routines.iter().find(|r| r.spec().name == name)
    }

    /// Routine names in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.routines.iter().map(|r| r.spec().name).collect()
    }

    /// All specs in registration order.
    pub fn specs(&self) -> Vec<&RoutineSpec> {
        self.routines.iter().map(|r| r.spec()).collect()
    }

    pub fn len(&self) -> usize {
        self.routines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.routines.is_empty()
    }
}

type Factory = Arc<dyn Fn() -> Arc<dyn Library> + Send + Sync>;

fn factories() -> &'static Mutex<HashMap<String, Factory>> {
    static F: OnceLock<Mutex<HashMap<String, Factory>>> = OnceLock::new();
    F.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Install a library factory under `path` (tests and downstream users add
/// custom libraries this way; the equivalent of dropping a new `.so` next
/// to the server).
pub fn install_factory(path: &str, f: impl Fn() -> Arc<dyn Library> + Send + Sync + 'static) {
    factories().lock().unwrap().insert(path.to_string(), Arc::new(f));
}

/// Resolve a library path to an instance. Supported schemes:
/// * `builtin:elemlib` — the bundled Elemental-substitute library;
/// * any path previously installed with [`install_factory`].
pub fn load_library(path: &str) -> Result<Arc<dyn Library>> {
    if path == "builtin:elemlib" {
        return Ok(Arc::new(crate::ali::elemlib::ElemLib::new()));
    }
    if let Some(f) = factories().lock().unwrap().get(path) {
        return Ok(f());
    }
    Err(Error::Ali(format!(
        "cannot load library from {path:?}: unknown scheme/factory \
         (native dlopen is out of scope in this reproduction; use \
         `builtin:elemlib` or install_factory)"
    )))
}

/// Per-worker table of loaded libraries, name -> instance.
#[derive(Default)]
pub struct LibraryRegistry {
    libs: HashMap<String, Arc<dyn Library>>,
}

impl LibraryRegistry {
    pub fn new() -> LibraryRegistry {
        LibraryRegistry::default()
    }

    /// Register `name` from `path`. Idempotent for the same name.
    pub fn register(&mut self, name: &str, path: &str) -> Result<()> {
        if self.libs.contains_key(name) {
            return Ok(());
        }
        let lib = load_library(path)?;
        self.libs.insert(name.to_string(), lib);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Arc<dyn Library>> {
        self.libs.get(name).ok_or_else(|| {
            Error::Ali(format!(
                "library {name:?} not registered (loaded: {:?})",
                self.libs.keys().collect::<Vec<_>>()
            ))
        })
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.libs.keys().map(|s| s.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ali::{RoutineCtx, RoutineOutput};
    use crate::protocol::Params;

    struct NoopLib;

    impl Library for NoopLib {
        fn name(&self) -> &str {
            "noop"
        }

        fn routines(&self) -> Vec<&'static str> {
            vec!["noop"]
        }

        fn run(
            &self,
            _routine: &str,
            _params: &Params,
            _ctx: &mut RoutineCtx<'_>,
        ) -> crate::Result<RoutineOutput> {
            Ok(RoutineOutput::default())
        }
    }

    #[test]
    fn builtin_elemlib_loads() {
        let mut reg = LibraryRegistry::new();
        reg.register("elemlib", "builtin:elemlib").unwrap();
        assert!(reg.get("elemlib").is_ok());
        assert_eq!(reg.get("elemlib").unwrap().name(), "elemlib");
        // idempotent
        reg.register("elemlib", "builtin:elemlib").unwrap();
        assert_eq!(reg.loaded().len(), 1);
    }

    #[test]
    fn unknown_path_rejected() {
        let mut reg = LibraryRegistry::new();
        assert!(reg.register("x", "/usr/lib/libfoo.so").is_err());
        assert!(reg.get("x").is_err());
    }

    #[test]
    fn custom_factory_roundtrip() {
        install_factory("test:noop", || Arc::new(NoopLib));
        let mut reg = LibraryRegistry::new();
        reg.register("mynoop", "test:noop").unwrap();
        assert_eq!(reg.get("mynoop").unwrap().name(), "noop");
    }
}
