//! Minimal leveled logger — the `spdlog` substitute from the paper's
//! dependency list. Thread-safe, zero-dependency, with per-component tags.
//!
//! Every line carries a wall-clock UTC timestamp (cross-process
//! correlation) plus the process-uptime seconds, the level, and the
//! component tag. Lines emitted while a telemetry trace context is
//! active on the thread (`telemetry::trace::push_trace_ctx`, set around
//! routine execution on every worker rank) additionally carry
//! `trace=<job trace id>@<component tag>`. Set
//! `ALCHEMIST_LOG_FORMAT=json` for structured one-object-per-line
//! output.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Log severity. Ordered so that an `AtomicU8` threshold works.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
    Off = 5,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "trace" => Level::Trace,
            "debug" => Level::Debug,
            "info" => Level::Info,
            "warn" => Level::Warn,
            "error" => Level::Error,
            "off" => Level::Off,
            _ => return None,
        })
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Trace => "TRACE",
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
            Level::Off => "OFF  ",
        }
    }
}

/// Set the global log threshold (also honours `ALCHEMIST_LOG` at startup).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialise from the `ALCHEMIST_LOG` environment variable, if set.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("ALCHEMIST_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Seconds since process start, for compact timestamps.
fn uptime() -> f64 {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Whether `ALCHEMIST_LOG_FORMAT=json` was set at first log call.
fn json_format() -> bool {
    static JSON: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *JSON.get_or_init(|| {
        std::env::var("ALCHEMIST_LOG_FORMAT")
            .map(|v| v.eq_ignore_ascii_case("json"))
            .unwrap_or(false)
    })
}

/// Proleptic-Gregorian civil date from days since 1970-01-01
/// (Hinnant's `civil_from_days`).
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = (z - era * 146_097) as u64;
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe as i64 + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32;
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// `2026-08-08T12:34:56.789Z` for a unix-micros wall-clock reading.
pub(crate) fn format_utc(micros: u64) -> String {
    let secs = micros / 1_000_000;
    let millis = (micros % 1_000_000) / 1000;
    let (y, mo, d) = civil_from_days((secs / 86_400) as i64);
    let tod = secs % 86_400;
    format!(
        "{y:04}-{mo:02}-{d:02}T{:02}:{:02}:{:02}.{millis:03}Z",
        tod / 3600,
        (tod % 3600) / 60,
        tod % 60
    )
}

fn now_micros() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_micros() as u64).unwrap_or(0)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[doc(hidden)]
pub fn log(level: Level, component: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let ts = format_utc(now_micros());
    let trace = crate::telemetry::trace::current_trace();
    let line = if json_format() {
        let trace_fields = match &trace {
            Some((id, tag)) => {
                format!(", \"trace_id\": {id}, \"span_source\": \"{}\"", json_escape(tag))
            }
            None => String::new(),
        };
        format!(
            "{{\"ts\": \"{ts}\", \"uptime\": {:.3}, \"level\": \"{}\", \
             \"component\": \"{}\"{trace_fields}, \"msg\": \"{}\"}}\n",
            uptime(),
            level.tag().trim(),
            json_escape(component),
            json_escape(&format!("{args}"))
        )
    } else {
        let trace_tag = match &trace {
            Some((id, tag)) => format!(" [trace {id}@{tag}]"),
            None => String::new(),
        };
        format!(
            "[{ts}] [{:9.3}] [{}] [{}]{trace_tag} {}\n",
            uptime(),
            level.tag(),
            component,
            args
        )
    };
    let _ = std::io::stderr().write_all(line.as_bytes());
}

/// `log!(Level::Info, "server", "worker {} up", id)`
#[macro_export]
macro_rules! log {
    ($level:expr, $component:expr, $($arg:tt)*) => {
        $crate::logging::log($level, $component, format_args!($($arg)*))
    };
}

/// Component-tagged convenience macros.
#[macro_export]
macro_rules! info {
    ($component:expr, $($arg:tt)*) => { $crate::log!($crate::logging::Level::Info, $component, $($arg)*) };
}
#[macro_export]
macro_rules! debugln {
    ($component:expr, $($arg:tt)*) => { $crate::log!($crate::logging::Level::Debug, $component, $($arg)*) };
}
#[macro_export]
macro_rules! warnln {
    ($component:expr, $($arg:tt)*) => { $crate::log!($crate::logging::Level::Warn, $component, $($arg)*) };
}
#[macro_export]
macro_rules! errorln {
    ($component:expr, $($arg:tt)*) => { $crate::log!($crate::logging::Level::Error, $component, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("OFF"), Some(Level::Off));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn threshold_filters() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }

    #[test]
    fn utc_formatting_known_instants() {
        assert_eq!(format_utc(0), "1970-01-01T00:00:00.000Z");
        // 2004-02-29T12:00:00.500Z — leap-year day (1078056000 s)
        assert_eq!(format_utc(1_078_056_000_500_000), "2004-02-29T12:00:00.500Z");
        // 2026-08-08T00:00:00Z = 1786147200 s
        assert_eq!(format_utc(1_786_147_200_000_000), "2026-08-08T00:00:00.000Z");
    }

    #[test]
    fn json_escaping_covers_controls() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
        assert_eq!(json_escape("plain"), "plain");
    }
}
