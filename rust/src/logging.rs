//! Minimal leveled logger — the `spdlog` substitute from the paper's
//! dependency list. Thread-safe, zero-dependency, with per-component tags.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Log severity. Ordered so that an `AtomicU8` threshold works.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
    Off = 5,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "trace" => Level::Trace,
            "debug" => Level::Debug,
            "info" => Level::Info,
            "warn" => Level::Warn,
            "error" => Level::Error,
            "off" => Level::Off,
            _ => return None,
        })
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Trace => "TRACE",
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
            Level::Off => "OFF  ",
        }
    }
}

/// Set the global log threshold (also honours `ALCHEMIST_LOG` at startup).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialise from the `ALCHEMIST_LOG` environment variable, if set.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("ALCHEMIST_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Seconds since process start, for compact timestamps.
fn uptime() -> f64 {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

#[doc(hidden)]
pub fn log(level: Level, component: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let line = format!("[{:9.3}] [{}] [{}] {}\n", uptime(), level.tag(), component, args);
    let _ = std::io::stderr().write_all(line.as_bytes());
}

/// `log!(Level::Info, "server", "worker {} up", id)`
#[macro_export]
macro_rules! log {
    ($level:expr, $component:expr, $($arg:tt)*) => {
        $crate::logging::log($level, $component, format_args!($($arg)*))
    };
}

/// Component-tagged convenience macros.
#[macro_export]
macro_rules! info {
    ($component:expr, $($arg:tt)*) => { $crate::log!($crate::logging::Level::Info, $component, $($arg)*) };
}
#[macro_export]
macro_rules! debugln {
    ($component:expr, $($arg:tt)*) => { $crate::log!($crate::logging::Level::Debug, $component, $($arg)*) };
}
#[macro_export]
macro_rules! warnln {
    ($component:expr, $($arg:tt)*) => { $crate::log!($crate::logging::Level::Warn, $component, $($arg)*) };
}
#[macro_export]
macro_rules! errorln {
    ($component:expr, $($arg:tt)*) => { $crate::log!($crate::logging::Level::Error, $component, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("OFF"), Some(Level::Off));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn threshold_filters() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }
}
