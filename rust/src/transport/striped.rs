//! Striped multi-connection transfers: N lanes per owner for fat pipes.
//!
//! A single TCP stream often cannot fill a high-bandwidth path (one
//! congestion window, one kernel copy pipeline). Striping opens
//! `stripes` connections per owner and spreads slabs across them:
//!
//! * **push** — the router round-robins full slab batches over an
//!   owner's lanes; every lane runs its own `PutDone` barrier, and
//!   `push_rows` only returns once *all* lanes of all owners acked, so
//!   the completion guarantee is unchanged (each row's frames stay
//!   ordered within their lane, and every lane is drained).
//! * **fetch** — the requested row range is partitioned into `stripes`
//!   contiguous sub-ranges per owner ([`stripe_ranges`]); each lane
//!   streams one sub-range, and the owner's results are delivered in
//!   stripe order. Workers stream a range in ascending global-index
//!   order, so the per-owner merge is deterministic and index-sorted —
//!   byte-for-byte the row set a single connection would have produced.
//!
//! The connector itself is deliberately thin: each `dial` opens one more
//! lane over the inner transport (so striping composes with the UDS fast
//! path); the lane bookkeeping lives in `client/transfer.rs`.

use super::{Connector, Endpoint, Transport, TransportFeatures};
use crate::Result;

/// Opens one lane per `dial` over an inner connector.
pub struct StripedConnector {
    inner: Box<dyn Connector>,
    stripes: usize,
}

impl StripedConnector {
    pub fn new(inner: Box<dyn Connector>, stripes: usize) -> StripedConnector {
        StripedConnector { inner, stripes: stripes.max(1) }
    }

    /// Lanes per owner.
    pub fn stripes(&self) -> usize {
        self.stripes
    }
}

impl Connector for StripedConnector {
    fn name(&self) -> &'static str {
        "striped"
    }

    fn features(&self) -> TransportFeatures {
        self.inner.features()
    }

    fn dial(&self, ep: &Endpoint) -> Result<Transport> {
        self.inner.dial(ep)
    }
}

/// Partition `[start, end)` into up to `stripes` contiguous, non-empty,
/// ascending sub-ranges that exactly cover it (ceil division, so the
/// first ranges are at most one unit longer than the last).
pub fn stripe_ranges(start: u64, end: u64, stripes: usize) -> Vec<(u64, u64)> {
    let stripes = stripes.max(1) as u64;
    let span = end.saturating_sub(start);
    if span == 0 {
        return Vec::new();
    }
    let per = span.div_ceil(stripes);
    let mut out = Vec::with_capacity(stripes as usize);
    let mut cur = start;
    while cur < end {
        let next = (cur + per).min(end);
        out.push((cur, next));
        cur = next;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_exactly_in_order() {
        for (start, end, stripes) in
            [(0u64, 100u64, 4usize), (10, 11, 4), (5, 5, 3), (0, 7, 3), (3, 1000, 1), (0, 3, 8)]
        {
            let ranges = stripe_ranges(start, end, stripes);
            assert!(ranges.len() <= stripes.max(1));
            let mut cur = start;
            for &(s, e) in &ranges {
                assert_eq!(s, cur, "contiguous");
                assert!(e > s, "non-empty");
                cur = e;
            }
            assert_eq!(cur, if end > start { end } else { start }, "covers [start,end)");
            if end <= start {
                assert!(ranges.is_empty());
            }
        }
    }

    #[test]
    fn striped_connector_composes() {
        let inner = super::super::connector_for(super::super::TransportChoice::Tcp, true);
        let striped = StripedConnector::new(inner, 0);
        assert_eq!(striped.stripes(), 1, "stripe count is clamped");
        assert_eq!(striped.name(), "striped");
        assert!(striped.features().supports_nodelay);
    }
}
