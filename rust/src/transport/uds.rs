//! Unix-domain-socket fast path for co-located client ⇄ worker pairs.
//!
//! Same frames, same blocking I/O model as TCP, but the kernel skips the
//! loopback network stack entirely — no pseudo-checksums, no 64 KiB
//! loopback MTU segmentation, larger default buffers. Workers bind the
//! socket next to their TCP data listener and advertise the path in
//! their registration hello; it only reaches clients through the v9
//! `WorkersGranted` shape.

use std::os::unix::net::UnixStream;

use super::{Connector, Endpoint, Transport, TransportFeatures, TransportKind};
use crate::{Error, Result};

/// Dials the endpoint's advertised UDS path. Fails with a typed error
/// when the endpoint has none (pre-v9 server, or a non-unix worker).
#[derive(Debug, Clone, Copy)]
pub struct UdsConnector;

impl Connector for UdsConnector {
    fn name(&self) -> &'static str {
        "uds"
    }

    fn features(&self) -> TransportFeatures {
        TransportFeatures { supports_nodelay: false, local_only: true }
    }

    fn dial(&self, ep: &Endpoint) -> Result<Transport> {
        if ep.uds_addr.is_empty() {
            return Err(Error::Server(format!(
                "worker at {} advertised no UDS data address (pre-v9 server?)",
                ep.tcp_addr
            )));
        }
        let s = UnixStream::connect(&ep.uds_addr)?;
        Ok(Transport::new(TransportKind::Uds, Box::new(s)))
    }
}
