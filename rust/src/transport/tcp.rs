//! The classic TCP data-plane transport (what every session used before
//! v9, and what ≤ v8 peers and cross-host endpoints still use).

use std::net::TcpStream;

use super::{Connector, Endpoint, Transport, TransportFeatures, TransportKind};
use crate::Result;

/// Dials the endpoint's TCP data address, optionally disabling Nagle
/// (the `[transfer] nodelay` knob — small `PutDone`/`PutComplete` control
/// frames should not wait behind a coalescing timer).
#[derive(Debug, Clone, Copy)]
pub struct TcpConnector {
    pub nodelay: bool,
}

impl Connector for TcpConnector {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn features(&self) -> TransportFeatures {
        TransportFeatures { supports_nodelay: true, local_only: false }
    }

    fn dial(&self, ep: &Endpoint) -> Result<Transport> {
        let s = TcpStream::connect(&ep.tcp_addr)?;
        if self.nodelay {
            s.set_nodelay(true)?;
        }
        Ok(Transport::new(TransportKind::Tcp, Box::new(s)))
    }
}
