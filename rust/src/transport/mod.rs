//! Pluggable data-plane transports (transfer plane v2).
//!
//! The slab codec and the framing layer are transport-agnostic — every
//! data-plane socket is a blocking byte stream carrying `u32 LE length ||
//! payload` frames. This module puts a [`Transport`]/[`Connector`]
//! abstraction behind them so the client's sender/fetch pipelines can
//! dial:
//!
//! * **tcp** — the classic path, one `TcpStream` per owner;
//! * **uds** — a Unix-domain-socket fast path, auto-selected when the
//!   owner's TCP data address resolves to the local host *and* the worker
//!   advertised a UDS path (v9 sessions only — ≤ v8 servers never
//!   publish one, so old sessions stay on TCP by construction);
//! * **striped** — N connections per owner for fat pipes, with
//!   round-robin slab dispatch and a per-stripe `PutDone` barrier
//!   (`client/transfer.rs` owns the lane bookkeeping; this module
//!   provides the connector and the deterministic range partitioning).
//!
//! Workers serve every transport with the same `serve_data_conn` loop —
//! the frames are identical bytes whichever socket they cross.

pub mod striped;
pub mod tcp;
#[cfg(unix)]
pub mod uds;

use std::io::{Read, Write};
use std::net::SocketAddr;

use crate::protocol::{frame, Writer};
use crate::{Error, Result};

/// Where one worker's data plane can be dialed: always a TCP address,
/// plus a UDS path when the worker bound one ("" otherwise).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Endpoint {
    pub tcp_addr: String,
    pub uds_addr: String,
}

impl Endpoint {
    /// TCP-only endpoint (≤ v8 servers, mesh peers).
    pub fn tcp(addr: impl Into<String>) -> Endpoint {
        Endpoint { tcp_addr: addr.into(), uds_addr: String::new() }
    }

    /// True when the TCP address parses to a loopback IP — the UDS
    /// auto-selection rule (a UDS path advertised by a remote host names
    /// a file that does not exist here).
    pub fn is_local(&self) -> bool {
        self.tcp_addr
            .parse::<SocketAddr>()
            .map(|a| a.ip().is_loopback())
            .unwrap_or(false)
    }
}

/// Marker trait for the byte streams a [`Transport`] can wrap.
pub trait Stream: Read + Write + Send {}

impl<T: Read + Write + Send> Stream for T {}

/// Which wire a [`Transport`] runs over — keys the per-transport byte
/// counters in [`crate::metrics::TransferMetrics`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    Tcp,
    Uds,
}

impl TransportKind {
    pub const fn name(self) -> &'static str {
        match self {
            TransportKind::Tcp => "tcp",
            TransportKind::Uds => "uds",
        }
    }
}

/// One dialed data-plane connection: a boxed blocking stream plus the
/// kind tag telemetry wants. Implements `Read`/`Write` by delegation so
/// the framing helpers (and any code written against `TcpStream`) work
/// unchanged.
pub struct Transport {
    kind: TransportKind,
    stream: Box<dyn Stream>,
}

impl Transport {
    pub fn new(kind: TransportKind, stream: Box<dyn Stream>) -> Transport {
        Transport { kind, stream }
    }

    pub fn kind(&self) -> TransportKind {
        self.kind
    }

    /// Framed write (single syscall, reusable encode buffer); returns the
    /// bytes written including the length header.
    pub fn send_frame(
        &mut self,
        wbuf: &mut Writer,
        encode: impl FnOnce(&mut Writer),
    ) -> Result<usize> {
        frame::write_frame_with(&mut self.stream, wbuf, encode)
    }

    /// Framed read into a reusable buffer; returns the payload length.
    pub fn recv_frame_into(&mut self, buf: &mut Vec<u8>) -> Result<usize> {
        frame::read_frame_into(&mut self.stream, buf)
    }
}

impl std::fmt::Debug for Transport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Transport").field("kind", &self.kind).finish()
    }
}

impl Read for Transport {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.stream.read(buf)
    }
}

impl Write for Transport {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.stream.write(buf)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.stream.flush()
    }
}

/// Static capabilities of a connector — what the dial path may assume.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransportFeatures {
    /// The underlying socket honors a no-delay (anti-Nagle) knob.
    pub supports_nodelay: bool,
    /// Only endpoints on this host can be dialed.
    pub local_only: bool,
}

/// Dials [`Endpoint`]s into [`Transport`]s. Implementations must be
/// shareable across the sender/fetch thread pools.
pub trait Connector: Send + Sync {
    /// Short name for logs, bench sweep labels and error messages.
    fn name(&self) -> &'static str;

    fn features(&self) -> TransportFeatures;

    fn dial(&self, ep: &Endpoint) -> Result<Transport>;
}

/// How the `[transfer] transport` knob selects a connector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportChoice {
    /// UDS when the endpoint is local and advertises a path, else TCP.
    #[default]
    Auto,
    Tcp,
    Uds,
}

impl TransportChoice {
    pub fn parse(s: &str) -> Result<TransportChoice> {
        Ok(match s {
            "auto" => TransportChoice::Auto,
            "tcp" => TransportChoice::Tcp,
            "uds" => TransportChoice::Uds,
            _ => {
                return Err(Error::Config(format!(
                    "unknown transfer.transport {s:?} (expected auto|tcp|uds)"
                )))
            }
        })
    }

    pub const fn name(self) -> &'static str {
        match self {
            TransportChoice::Auto => "auto",
            TransportChoice::Tcp => "tcp",
            TransportChoice::Uds => "uds",
        }
    }
}

/// The auto-selection policy: try the UDS fast path when the endpoint is
/// provably co-located (loopback TCP address + advertised UDS path), fall
/// back to TCP — including when the UDS dial itself fails, e.g. a stale
/// socket file left by a restarted worker.
pub struct AutoConnector {
    tcp: tcp::TcpConnector,
}

impl AutoConnector {
    pub fn new(nodelay: bool) -> AutoConnector {
        AutoConnector { tcp: tcp::TcpConnector { nodelay } }
    }
}

impl Connector for AutoConnector {
    fn name(&self) -> &'static str {
        "auto"
    }

    fn features(&self) -> TransportFeatures {
        self.tcp.features()
    }

    fn dial(&self, ep: &Endpoint) -> Result<Transport> {
        #[cfg(unix)]
        if !ep.uds_addr.is_empty() && ep.is_local() {
            if let Ok(t) = uds::UdsConnector.dial(ep) {
                return Ok(t);
            }
        }
        self.tcp.dial(ep)
    }
}

#[cfg(not(unix))]
struct Unsupported(&'static str);

#[cfg(not(unix))]
impl Connector for Unsupported {
    fn name(&self) -> &'static str {
        "unsupported"
    }

    fn features(&self) -> TransportFeatures {
        TransportFeatures { supports_nodelay: false, local_only: false }
    }

    fn dial(&self, _ep: &Endpoint) -> Result<Transport> {
        Err(Error::Config(self.0.into()))
    }
}

#[cfg(unix)]
fn uds_connector() -> Box<dyn Connector> {
    Box::new(uds::UdsConnector)
}

#[cfg(not(unix))]
fn uds_connector() -> Box<dyn Connector> {
    Box::new(Unsupported("transfer.transport = \"uds\" requires a unix host"))
}

/// Build the connector for a configured transport choice.
pub fn connector_for(choice: TransportChoice, nodelay: bool) -> Box<dyn Connector> {
    match choice {
        TransportChoice::Auto => Box::new(AutoConnector::new(nodelay)),
        TransportChoice::Tcp => Box::new(tcp::TcpConnector { nodelay }),
        TransportChoice::Uds => uds_connector(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_locality_rule() {
        assert!(Endpoint::tcp("127.0.0.1:4000").is_local());
        assert!(Endpoint::tcp("[::1]:4000").is_local());
        assert!(!Endpoint::tcp("10.0.0.7:4000").is_local());
        assert!(!Endpoint::tcp("not-an-addr").is_local());
    }

    #[test]
    fn choice_parses_and_rejects() {
        for c in [TransportChoice::Auto, TransportChoice::Tcp, TransportChoice::Uds] {
            assert_eq!(TransportChoice::parse(c.name()).unwrap(), c);
        }
        assert!(TransportChoice::parse("rdma").is_err());
    }

    #[test]
    fn tcp_connector_dials_and_frames() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let got = frame::read_frame(&mut s).unwrap();
            frame::write_frame(&mut s, &got).unwrap();
        });
        let conn = connector_for(TransportChoice::Tcp, true);
        assert_eq!(conn.name(), "tcp");
        assert!(conn.features().supports_nodelay);
        let mut tr = conn.dial(&Endpoint::tcp(addr)).unwrap();
        assert_eq!(tr.kind(), TransportKind::Tcp);
        let mut w = Writer::new();
        tr.send_frame(&mut w, |w| w.put_u8(42)).unwrap();
        let mut buf = Vec::new();
        assert_eq!(tr.recv_frame_into(&mut buf).unwrap(), 1);
        assert_eq!(buf, vec![42]);
        t.join().unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn auto_prefers_uds_and_falls_back() {
        use std::os::unix::net::UnixListener;
        let dir = std::env::temp_dir().join(format!("alch-transport-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("auto.sock");
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let got = frame::read_frame(&mut s).unwrap();
            frame::write_frame(&mut s, &got).unwrap();
        });
        let ep = Endpoint {
            tcp_addr: "127.0.0.1:1".into(), // unused: UDS wins
            uds_addr: path.to_string_lossy().into_owned(),
        };
        let conn = connector_for(TransportChoice::Auto, true);
        let mut tr = conn.dial(&ep).unwrap();
        assert_eq!(tr.kind(), TransportKind::Uds);
        let mut w = Writer::new();
        tr.send_frame(&mut w, |w| w.put_u8(7)).unwrap();
        let mut buf = Vec::new();
        tr.recv_frame_into(&mut buf).unwrap();
        assert_eq!(buf, vec![7]);
        t.join().unwrap();
        let _ = std::fs::remove_file(&path);

        // non-local endpoints must not try the UDS path even if one is
        // advertised (the file belongs to another host's namespace)
        let remote = Endpoint { tcp_addr: "10.9.8.7:1".into(), uds_addr: "/tmp/x.sock".into() };
        assert!(!remote.is_local());

        // forced uds with no advertised path is a typed error
        let bare = Endpoint::tcp("127.0.0.1:1");
        assert!(connector_for(TransportChoice::Uds, false).dial(&bare).is_err());
    }
}
