//! Pull-based telemetry export: the merged report + its renderings.
//!
//! The driver answers `ClientMsg::FetchTelemetry` with one
//! [`TelemetryReport`]: its own registry snapshot merged with every
//! session worker's (pulled over the data plane, names prefixed
//! `w{id}.`) plus the concatenated span buffers. The report renders as
//! a Prometheus-style text page, a JSON snapshot, or a
//! chrome://tracing-compatible event array (load the file via
//! `chrome://tracing` / Perfetto to see the per-job timeline).

use std::collections::BTreeMap;

use crate::protocol::{Reader, Writer};
use crate::telemetry::registry::RegistrySnapshot;
use crate::telemetry::trace::SpanRecord;
use crate::Result;

/// Decode guard: a hostile frame must not drive span decoding into an
/// unbounded allocation (mirrors `Reader::cap_hint` discipline).
const MAX_WIRE_SPANS: usize = 1 << 20;

/// One registry snapshot + one span buffer — the v8 pull payload, from
/// a single component (worker reply) or merged (driver reply).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TelemetryReport {
    pub registry: RegistrySnapshot,
    pub spans: Vec<SpanRecord>,
}

impl TelemetryReport {
    /// Fold another component's report in: registry names are summed
    /// (prefix them first if they must stay distinct), spans concatenate.
    pub fn merge(&mut self, other: TelemetryReport) {
        self.registry.merge(&other.registry);
        self.spans.extend(other.spans);
    }

    /// Spans of one job's trace, time-ordered.
    pub fn spans_for(&self, trace_id: u64) -> Vec<SpanRecord> {
        let mut out: Vec<SpanRecord> =
            self.spans.iter().filter(|s| s.trace_id == trace_id).cloned().collect();
        out.sort_by_key(|s| (s.start_us, s.dur_us));
        out
    }

    /// Distinct span sources, sorted ("driver", "w0", "w1", ...).
    pub fn sources(&self) -> Vec<String> {
        let mut v: Vec<String> = self.spans.iter().map(|s| s.source.clone()).collect();
        v.sort();
        v.dedup();
        v
    }

    /// `[min start, max end]` over the spans (micros), if any.
    pub fn span_window(&self) -> Option<(u64, u64)> {
        let lo = self.spans.iter().map(|s| s.start_us).min()?;
        let hi = self.spans.iter().map(|s| s.end_us()).max()?;
        Some((lo, hi))
    }

    pub fn encode_into(&self, w: &mut Writer) {
        self.registry.encode_into(w);
        w.put_u32(self.spans.len() as u32);
        for s in &self.spans {
            s.encode_into(w);
        }
    }

    pub fn decode(r: &mut Reader) -> Result<TelemetryReport> {
        let registry = RegistrySnapshot::decode(r)?;
        let n = r.get_u32()? as usize;
        if n > MAX_WIRE_SPANS {
            return Err(crate::Error::Protocol(format!("telemetry report claims {n} spans")));
        }
        let mut spans = Vec::with_capacity(r.cap_hint(n, 32));
        for _ in 0..n {
            spans.push(SpanRecord::decode(r)?);
        }
        Ok(TelemetryReport { registry, spans })
    }

    /// Prometheus text exposition (counters/gauges plus
    /// `<phase>_seconds_total` / `<phase>_events_total` pairs).
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.registry.counters {
            let name = sanitize_metric_name(k);
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        }
        for (k, v) in &self.registry.gauges {
            let name = sanitize_metric_name(k);
            out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
        }
        for (k, v) in &self.registry.phases {
            let name = sanitize_metric_name(k);
            out.push_str(&format!(
                "# TYPE {name}_seconds_total counter\n{name}_seconds_total {}\n",
                fmt_f64(v.secs)
            ));
            out.push_str(&format!(
                "# TYPE {name}_events_total counter\n{name}_events_total {}\n",
                v.count
            ));
        }
        out
    }

    /// JSON snapshot: `{"counters":{},"gauges":{},"phases":{},"spans":[]}`.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"counters\": {");
        out.push_str(&join_entries(
            self.registry.counters.iter().map(|(k, v)| (k.as_str(), v.to_string())),
        ));
        out.push_str("},\n  \"gauges\": {");
        out.push_str(&join_entries(
            self.registry.gauges.iter().map(|(k, v)| (k.as_str(), v.to_string())),
        ));
        out.push_str("},\n  \"phases\": {");
        out.push_str(&join_entries(self.registry.phases.iter().map(|(k, v)| {
            (k.as_str(), format!("{{\"secs\": {}, \"count\": {}}}", fmt_f64(v.secs), v.count))
        })));
        out.push_str("},\n  \"spans\": [");
        let spans: Vec<String> = self
            .spans
            .iter()
            .map(|s| {
                format!(
                    "{{\"trace_id\": {}, \"name\": \"{}\", \"source\": \"{}\", \
                     \"start_us\": {}, \"dur_us\": {}}}",
                    s.trace_id,
                    json_escape(&s.name),
                    json_escape(&s.source),
                    s.start_us,
                    s.dur_us
                )
            })
            .collect();
        out.push_str(&spans.join(", "));
        out.push_str("]\n}\n");
        out
    }

    /// chrome://tracing "trace event format" JSON: one complete (`"X"`)
    /// event per span plus thread-name metadata per source.
    pub fn chrome_trace(&self) -> String {
        let mut tids: BTreeMap<&str, u64> = BTreeMap::new();
        for s in &self.spans {
            let next = tids.len() as u64;
            tids.entry(s.source.as_str()).or_insert(next);
        }
        let mut events: Vec<String> = tids
            .iter()
            .map(|(src, tid)| {
                format!(
                    "{{\"ph\": \"M\", \"pid\": 1, \"tid\": {tid}, \"name\": \"thread_name\", \
                     \"args\": {{\"name\": \"{}\"}}}}",
                    json_escape(src)
                )
            })
            .collect();
        for s in &self.spans {
            let tid = tids[s.source.as_str()];
            events.push(format!(
                "{{\"ph\": \"X\", \"pid\": 1, \"tid\": {tid}, \"name\": \"{}\", \
                 \"ts\": {}, \"dur\": {}, \"args\": {{\"trace_id\": {}}}}}",
                json_escape(&s.name),
                s.start_us,
                s.dur_us,
                s.trace_id
            ));
        }
        format!("[\n{}\n]\n", events.join(",\n"))
    }
}

/// Prometheus metric names allow `[a-zA-Z0-9_:]`; everything else
/// (dots from the `w{id}.` prefixes) becomes `_`.
fn sanitize_metric_name(s: &str) -> String {
    let mut out: String = s
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect();
    if out.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        out.insert(0, '_');
    }
    out
}

/// f64 as JSON-safe text (never NaN/Inf from our accumulators, but be
/// defensive — JSON has no literals for them).
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.9}")
    } else {
        "0".into()
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn join_entries<'a>(entries: impl Iterator<Item = (&'a str, String)>) -> String {
    let parts: Vec<String> =
        entries.map(|(k, v)| format!("\"{}\": {}", json_escape(k), v)).collect();
    parts.join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::{MetricsRegistry, PhaseStat};

    fn sample() -> TelemetryReport {
        let reg = MetricsRegistry::new();
        reg.counter("w0.frames").inc(3);
        reg.gauge("sched.queue_depth").set(1);
        reg.phase("w0.compute").add(std::time::Duration::from_millis(5));
        TelemetryReport {
            registry: reg.snapshot(),
            spans: vec![
                SpanRecord {
                    trace_id: 7,
                    name: "queue_wait".into(),
                    source: "driver".into(),
                    start_us: 100,
                    dur_us: 20,
                },
                SpanRecord {
                    trace_id: 7,
                    name: "compute".into(),
                    source: "w0".into(),
                    start_us: 120,
                    dur_us: 80,
                },
                SpanRecord {
                    trace_id: 0,
                    name: "grant".into(),
                    source: "driver".into(),
                    start_us: 50,
                    dur_us: 10,
                },
            ],
        }
    }

    #[test]
    fn report_wire_roundtrip() {
        let rep = sample();
        let mut w = Writer::new();
        rep.encode_into(&mut w);
        let bytes = w.into_bytes();
        let got = TelemetryReport::decode(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(got, rep);
    }

    #[test]
    fn merge_concatenates_spans_and_sums_registry() {
        let mut a = sample();
        let b = sample();
        a.merge(b);
        assert_eq!(a.spans.len(), 6);
        assert_eq!(a.registry.counters["w0.frames"], 6);
    }

    #[test]
    fn per_trace_filter_and_window() {
        let rep = sample();
        let j7 = rep.spans_for(7);
        assert_eq!(j7.len(), 2);
        assert!(j7[0].start_us <= j7[1].start_us, "time-ordered");
        assert_eq!(rep.span_window(), Some((50, 200)));
        assert_eq!(rep.sources(), vec!["driver".to_string(), "w0".to_string()]);
    }

    #[test]
    fn prometheus_rendering_sanitizes_names() {
        let text = sample().prometheus();
        assert!(text.contains("# TYPE w0_frames counter"));
        assert!(text.contains("w0_frames 3"));
        assert!(text.contains("sched_queue_depth 1"));
        assert!(text.contains("w0_compute_seconds_total"));
        assert!(text.contains("w0_compute_events_total 1"));
        assert!(!text.contains('.'), "dots must be sanitized away:\n{text}");
    }

    #[test]
    fn json_snapshot_is_balanced_and_complete() {
        let js = sample().to_json();
        for (open, close) in [('{', '}'), ('[', ']')] {
            let o = js.matches(open).count();
            let c = js.matches(close).count();
            assert_eq!(o, c, "unbalanced {open}{close} in:\n{js}");
        }
        assert!(js.contains("\"w0.frames\": 3"));
        assert!(js.contains("\"queue_wait\""));
        assert!(js.contains("\"count\": 1"));
    }

    #[test]
    fn chrome_trace_has_events_and_thread_names() {
        let ct = sample().chrome_trace();
        assert!(ct.starts_with("[\n"));
        assert!(ct.contains("\"ph\": \"M\""));
        assert!(ct.contains("\"thread_name\""));
        assert_eq!(ct.matches("\"ph\": \"X\"").count(), 3);
        assert!(ct.contains("\"ts\": 120"));
        // one tid per source, stable across events
        assert!(ct.contains("\"args\": {\"name\": \"driver\"}"));
        assert!(ct.contains("\"args\": {\"name\": \"w0\"}"));
    }

    #[test]
    fn hostile_span_count_is_rejected() {
        let mut w = Writer::new();
        RegistrySnapshot {
            counters: Default::default(),
            gauges: Default::default(),
            phases: BTreeMap::from([("p".to_string(), PhaseStat { secs: 1.0, count: 1 })]),
        }
        .encode_into(&mut w);
        w.put_u32(u32::MAX); // absurd span count
        let bytes = w.into_bytes();
        assert!(TelemetryReport::decode(&mut Reader::new(&bytes)).is_err());
    }
}
