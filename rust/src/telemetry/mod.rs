//! Unified telemetry plane (protocol v8).
//!
//! The paper's entire evaluation is a phase breakdown — every Alchemist
//! call is reported as **send / compute / receive** (Table 1, Fig 3) —
//! but before this subsystem those numbers only existed inside offline
//! benches. This module is the live measurement substrate:
//!
//! * [`registry`] — a central [`MetricsRegistry`] of named counters,
//!   gauges and phase accumulators with **pre-registered atomic
//!   handles**: hot paths resolve a name once at setup and then pay a
//!   single relaxed atomic op per event (no `Mutex<BTreeMap<String,_>>`
//!   lock, no `String` allocation). The registry also serves compat
//!   views ([`CountersView`]/[`PhasesView`]) with the legacy
//!   `metrics::Counters`/`metrics::PhaseTimes` API so cold call sites
//!   did not have to change.
//! * [`trace`] — cross-process job tracing. Every job's `job_token`
//!   (minted at Submit by the driver) doubles as its **trace id**; it is
//!   already propagated through `WorkerCtl::RunRoutine` and the
//!   data-plane cancel/progress frames, so driver and every worker rank
//!   record [`SpanRecord`]s (queue-wait, validation, compute,
//!   teardown, …) into bounded per-component [`TelemetrySink`] ring
//!   buffers that stitch into one per-job timeline. Wall-clock span
//!   timestamps (unix micros) make the records comparable across
//!   processes on one host.
//! * [`export`] — the pull side: [`TelemetryReport`] (one registry
//!   snapshot + one span buffer) merges across driver + ranks and
//!   renders as a Prometheus-style text page, a JSON snapshot, or a
//!   chrome://tracing-compatible span export.
//!
//! Overhead budget: a disabled sink is one relaxed atomic load per span
//! site; an enabled one is a short critical section on a `VecDeque`
//! (bounded by `telemetry.span_buffer`). Counter handles are one
//! `fetch_add(Relaxed)`. `benches/micro_hotpaths.rs` asserts the
//! data-plane total stays under 2%.

pub mod export;
pub mod registry;
pub mod trace;

pub use export::TelemetryReport;
pub use registry::{
    CounterHandle, CountersView, GaugeHandle, MetricsRegistry, PhaseHandle, PhaseStat,
    PhasesView, RegistrySnapshot,
};
pub use trace::{
    current_trace, push_trace_ctx, unix_micros, SpanGuard, SpanRecord, TelemetrySink,
    TraceCtxGuard, AMBIENT_TRACE,
};
