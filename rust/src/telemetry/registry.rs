//! Central metrics registry with pre-registered atomic handles.
//!
//! Registration (`counter`/`gauge`/`phase`) takes a short lock on a name
//! map and hands back an `Arc`'d atomic cell; after that every event is
//! one relaxed atomic op with zero allocation. Snapshots clone the name
//! maps once and read each cell — safe to take while hot paths write.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::protocol::{Reader, Writer};
use crate::Result;

/// Pre-registered monotonic counter: `inc` is one `fetch_add(Relaxed)`.
#[derive(Debug, Clone, Default)]
pub struct CounterHandle(Arc<AtomicU64>);

impl CounterHandle {
    pub fn inc(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Pre-registered point-in-time level (can move both ways); same
/// surface as `metrics::Gauge` so bundle fields could swap types.
#[derive(Debug, Clone, Default)]
pub struct GaugeHandle(Arc<AtomicI64>);

impl GaugeHandle {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn dec(&self) {
        self.add(-1);
    }

    pub fn add(&self, delta: i64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn set(&self, value: i64) {
        self.0.store(value, Ordering::Relaxed);
    }

    /// Raise to `value` if higher (high-water marks).
    pub fn set_max(&self, value: i64) {
        self.0.fetch_max(value, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Accumulated duration + event count for one named phase.
#[derive(Debug, Default)]
pub struct PhaseCell {
    nanos: AtomicU64,
    count: AtomicU64,
}

/// Pre-registered phase accumulator: `add` is two relaxed atomic adds.
#[derive(Debug, Clone, Default)]
pub struct PhaseHandle(Arc<PhaseCell>);

impl PhaseHandle {
    pub fn add(&self, d: Duration) {
        self.0.nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_secs(&self, s: f64) {
        self.0.nanos.fetch_add((s.max(0.0) * 1e9) as u64, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn secs(&self) -> f64 {
        self.0.nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }
}

/// Point-in-time reading of one phase accumulator.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PhaseStat {
    pub secs: f64,
    pub count: u64,
}

/// Named counters/gauges/phases. Instantiable (one per component —
/// driver scheduler, each worker) so in-process deployments never
/// double-count; process-wide singletons (`metrics::transfer_metrics`)
/// embed their own instance.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, CounterHandle>>,
    gauges: Mutex<BTreeMap<String, GaugeHandle>>,
    phases: Mutex<BTreeMap<String, PhaseHandle>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or fetch) the counter `name`; the returned handle is
    /// the hot-path entry point.
    pub fn counter(&self, name: &str) -> CounterHandle {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> GaugeHandle {
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    pub fn phase(&self, name: &str) -> PhaseHandle {
        let mut m = self.phases.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Point-in-time copy of every registered metric.
    pub fn snapshot(&self) -> RegistrySnapshot {
        let counters = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect();
        let gauges =
            self.gauges.lock().unwrap().iter().map(|(k, v)| (k.clone(), v.get())).collect();
        let phases = self
            .phases
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), PhaseStat { secs: v.secs(), count: v.count() }))
            .collect();
        RegistrySnapshot { counters, gauges, phases }
    }
}

/// Legacy-`metrics::Counters`-shaped view over a registry: string-keyed
/// `add`/`get` (one lock + possible allocation per call) for cold call
/// sites; hot paths hold [`CounterHandle`]s into the same cells instead.
#[derive(Debug, Clone)]
pub struct CountersView {
    reg: Arc<MetricsRegistry>,
}

impl CountersView {
    pub fn new(reg: Arc<MetricsRegistry>) -> Self {
        CountersView { reg }
    }

    pub fn add(&self, name: &str, n: u64) {
        self.reg.counter(name).inc(n);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.reg.counter(name).get()
    }

    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.reg.snapshot().counters
    }
}

/// Legacy-`metrics::PhaseTimes`-shaped view over a registry.
#[derive(Debug, Clone)]
pub struct PhasesView {
    reg: Arc<MetricsRegistry>,
}

impl PhasesView {
    pub fn new(reg: Arc<MetricsRegistry>) -> Self {
        PhasesView { reg }
    }

    pub fn add(&self, name: &str, d: Duration) {
        self.reg.phase(name).add(d);
    }

    pub fn get(&self, name: &str) -> Duration {
        Duration::from_secs_f64(self.get_secs(name))
    }

    pub fn get_secs(&self, name: &str) -> f64 {
        self.reg.phase(name).secs()
    }

    pub fn total(&self) -> Duration {
        let total: f64 = self.snapshot().values().sum();
        Duration::from_secs_f64(total)
    }

    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        self.reg
            .snapshot()
            .phases
            .into_iter()
            .map(|(k, v)| (k, v.secs))
            .collect()
    }
}

/// Point-in-time copy of a registry — the v8 wire payload unit. Merging
/// sums counters/gauges and adds phase time+count; `prefixed` namespaces
/// every name (the driver tags worker snapshots `w{id}.` before merge).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RegistrySnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub phases: BTreeMap<String, PhaseStat>,
}

impl RegistrySnapshot {
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.phases.is_empty()
    }

    pub fn merge(&mut self, other: &RegistrySnapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_default() += v;
        }
        for (k, v) in &other.gauges {
            *self.gauges.entry(k.clone()).or_default() += v;
        }
        for (k, v) in &other.phases {
            let e = self.phases.entry(k.clone()).or_default();
            e.secs += v.secs;
            e.count += v.count;
        }
    }

    pub fn prefixed(self, prefix: &str) -> RegistrySnapshot {
        RegistrySnapshot {
            counters: self.counters.into_iter().map(|(k, v)| (format!("{prefix}{k}"), v)).collect(),
            gauges: self.gauges.into_iter().map(|(k, v)| (format!("{prefix}{k}"), v)).collect(),
            phases: self.phases.into_iter().map(|(k, v)| (format!("{prefix}{k}"), v)).collect(),
        }
    }

    pub fn encode_into(&self, w: &mut Writer) {
        w.put_u32(self.counters.len() as u32);
        for (k, v) in &self.counters {
            w.put_str(k);
            w.put_u64(*v);
        }
        w.put_u32(self.gauges.len() as u32);
        for (k, v) in &self.gauges {
            w.put_str(k);
            w.put_i64(*v);
        }
        w.put_u32(self.phases.len() as u32);
        for (k, v) in &self.phases {
            w.put_str(k);
            w.put_f64(v.secs);
            w.put_u64(v.count);
        }
    }

    pub fn decode(r: &mut Reader) -> Result<RegistrySnapshot> {
        let mut out = RegistrySnapshot::default();
        let n = r.get_u32()? as usize;
        for _ in 0..n {
            let k = r.get_str()?;
            out.counters.insert(k, r.get_u64()?);
        }
        let n = r.get_u32()? as usize;
        for _ in 0..n {
            let k = r.get_str()?;
            out.gauges.insert(k, r.get_i64()?);
        }
        let n = r.get_u32()? as usize;
        for _ in 0..n {
            let k = r.get_str()?;
            let secs = r.get_f64()?;
            let count = r.get_u64()?;
            out.phases.insert(k, PhaseStat { secs, count });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_share_cells_with_views() {
        let reg = Arc::new(MetricsRegistry::new());
        let h = reg.counter("bytes");
        h.inc(100);
        h.inc(28);
        let view = CountersView::new(reg.clone());
        assert_eq!(view.get("bytes"), 128);
        view.add("bytes", 2);
        assert_eq!(h.get(), 130);
        assert_eq!(view.get("missing"), 0);
    }

    #[test]
    fn gauge_handle_full_surface() {
        let reg = MetricsRegistry::new();
        let g = reg.gauge("depth");
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.set(7);
        g.set_max(3);
        assert_eq!(g.get(), 7);
        g.set_max(9);
        assert_eq!(g.get(), 9);
        g.add(-5);
        assert_eq!(g.get(), 4);
    }

    #[test]
    fn phases_accumulate_with_counts() {
        let reg = Arc::new(MetricsRegistry::new());
        let p = reg.phase("send");
        p.add(Duration::from_millis(10));
        p.add_secs(0.015);
        assert!((p.secs() - 0.025).abs() < 1e-6);
        assert_eq!(p.count(), 2);
        let view = PhasesView::new(reg);
        assert!((view.get_secs("send") - 0.025).abs() < 1e-6);
        view.add("compute", Duration::from_millis(75));
        assert!((view.total().as_secs_f64() - 0.1).abs() < 1e-3);
    }

    #[test]
    fn snapshot_merge_and_prefix() {
        let reg = MetricsRegistry::new();
        reg.counter("frames").inc(3);
        reg.gauge("depth").set(2);
        reg.phase("compute").add(Duration::from_millis(5));
        let a = reg.snapshot().prefixed("w0.");
        assert_eq!(a.counters.get("w0.frames"), Some(&3));

        let mut merged = a.clone();
        merged.merge(&a);
        assert_eq!(merged.counters["w0.frames"], 6);
        assert_eq!(merged.gauges["w0.depth"], 4);
        assert_eq!(merged.phases["w0.compute"].count, 2);
        assert!(merged.phases["w0.compute"].secs > 0.009);
    }

    #[test]
    fn snapshot_wire_roundtrip() {
        let reg = MetricsRegistry::new();
        reg.counter("a").inc(7);
        reg.gauge("b").set(-3);
        reg.phase("c").add(Duration::from_micros(1500));
        let snap = reg.snapshot();
        let mut w = Writer::new();
        snap.encode_into(&mut w);
        let bytes = w.into_bytes();
        let got = RegistrySnapshot::decode(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(got, snap);
        assert!(!got.is_empty());
        assert!(RegistrySnapshot::default().is_empty());
    }

    #[test]
    fn truncated_snapshot_is_protocol_error() {
        let reg = MetricsRegistry::new();
        reg.counter("a").inc(1);
        let mut w = Writer::new();
        reg.snapshot().encode_into(&mut w);
        let bytes = w.into_bytes();
        assert!(RegistrySnapshot::decode(&mut Reader::new(&bytes[..bytes.len() - 3])).is_err());
    }
}
