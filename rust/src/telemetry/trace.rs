//! Cross-process span recording.
//!
//! A **trace id** is the job's `job_token` — minted at Submit on the
//! driver, stamped on `WorkerCtl::RunRoutine` and the data-plane
//! cancel/progress frames, so every component that sees the job can tag
//! its spans without new wire plumbing. Spans carry wall-clock start
//! times (unix micros) so driver and worker records stitch into one
//! timeline even across process boundaries on the same host.
//!
//! Components each own a bounded [`TelemetrySink`] ring buffer (one per
//! worker rank, one on the driver, one in the client context); the v8
//! `FetchTelemetry` pull drains copies of them toward the driver.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use crate::protocol::{Reader, Writer};
use crate::Result;

/// Trace id for spans not tied to any job (grants, session setup,
/// data-plane streams): they appear in the full timeline export but are
/// excluded from per-job filtering.
pub const AMBIENT_TRACE: u64 = 0;

/// Wall-clock microseconds since the unix epoch.
pub fn unix_micros() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_micros() as u64).unwrap_or(0)
}

/// One recorded span. `source` identifies the recording component
/// ("driver", "w0", "client"); `trace_id` groups the spans of one job
/// (0 = ambient, see [`AMBIENT_TRACE`]).
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    pub trace_id: u64,
    pub name: String,
    pub source: String,
    pub start_us: u64,
    pub dur_us: u64,
}

impl SpanRecord {
    pub fn end_us(&self) -> u64 {
        self.start_us.saturating_add(self.dur_us)
    }

    pub fn encode_into(&self, w: &mut Writer) {
        w.put_u64(self.trace_id);
        w.put_str(&self.name);
        w.put_str(&self.source);
        w.put_u64(self.start_us);
        w.put_u64(self.dur_us);
    }

    pub fn decode(r: &mut Reader) -> Result<SpanRecord> {
        Ok(SpanRecord {
            trace_id: r.get_u64()?,
            name: r.get_str()?,
            source: r.get_str()?,
            start_us: r.get_u64()?,
            dur_us: r.get_u64()?,
        })
    }
}

/// Bounded per-component span buffer. Oldest spans are evicted once the
/// ring holds `cap` records (`telemetry.span_buffer`); evictions are
/// counted, never blocked on. Disabled sinks cost one relaxed atomic
/// load per call site.
#[derive(Debug)]
pub struct TelemetrySink {
    source: Mutex<String>,
    enabled: AtomicBool,
    cap: usize,
    spans: Mutex<VecDeque<SpanRecord>>,
    dropped: AtomicU64,
}

impl TelemetrySink {
    pub fn new(source: &str, cap: usize) -> TelemetrySink {
        TelemetrySink {
            source: Mutex::new(source.to_string()),
            enabled: AtomicBool::new(true),
            cap: cap.max(1),
            spans: Mutex::new(VecDeque::new()),
            dropped: AtomicU64::new(0),
        }
    }

    /// Re-tag the sink (workers learn their rank only at registration).
    pub fn set_source(&self, source: &str) {
        *self.source.lock().unwrap() = source.to_string();
    }

    pub fn source(&self) -> String {
        self.source.lock().unwrap().clone()
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Record a completed span. `start_us` is wall-clock
    /// ([`unix_micros`] taken when the phase began).
    pub fn record(&self, trace_id: u64, name: &str, start_us: u64, dur_us: u64) {
        if !self.enabled() {
            return;
        }
        let rec = SpanRecord {
            trace_id,
            name: name.to_string(),
            source: self.source(),
            start_us,
            dur_us,
        };
        let mut q = self.spans.lock().unwrap();
        if q.len() >= self.cap {
            q.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(rec);
    }

    /// Start a span now; it records itself on drop (or [`SpanGuard::done`]).
    pub fn span<'a>(&'a self, trace_id: u64, name: &'a str) -> SpanGuard<'a> {
        SpanGuard { sink: self, trace_id, name, start_us: unix_micros(), t: Instant::now() }
    }

    /// An instant marker (zero-duration span) — per-iteration progress
    /// ticks from `ProgressSink` land here.
    pub fn mark(&self, trace_id: u64, name: &str) {
        self.record(trace_id, name, unix_micros(), 0);
    }

    pub fn snapshot(&self) -> Vec<SpanRecord> {
        self.spans.lock().unwrap().iter().cloned().collect()
    }

    pub fn spans_for(&self, trace_id: u64) -> Vec<SpanRecord> {
        self.spans.lock().unwrap().iter().filter(|s| s.trace_id == trace_id).cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.spans.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn clear(&self) {
        self.spans.lock().unwrap().clear();
    }
}

/// RAII span: measures from construction to drop.
pub struct SpanGuard<'a> {
    sink: &'a TelemetrySink,
    trace_id: u64,
    name: &'a str,
    start_us: u64,
    t: Instant,
}

impl SpanGuard<'_> {
    /// Explicit finish (same as drop; reads better at call sites).
    pub fn done(self) {}
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let dur_us = self.t.elapsed().as_micros() as u64;
        self.sink.record(self.trace_id, self.name, self.start_us, dur_us);
    }
}

thread_local! {
    /// (trace id, component tag) of the innermost active span on this
    /// thread — injected into log lines by `logging::log`.
    static TRACE_CTX: RefCell<Vec<(u64, String)>> = const { RefCell::new(Vec::new()) };
}

/// Enter a trace context for the current thread; log lines emitted until
/// the guard drops carry `trace=<id>@<tag>`. Nests (inner wins).
pub fn push_trace_ctx(trace_id: u64, tag: &str) -> TraceCtxGuard {
    TRACE_CTX.with(|c| c.borrow_mut().push((trace_id, tag.to_string())));
    TraceCtxGuard { _priv: () }
}

/// The innermost active trace context, if any.
pub fn current_trace() -> Option<(u64, String)> {
    TRACE_CTX.with(|c| c.borrow().last().cloned())
}

/// Pops its trace context on drop.
pub struct TraceCtxGuard {
    _priv: (),
}

impl Drop for TraceCtxGuard {
    fn drop(&mut self) {
        TRACE_CTX.with(|c| {
            c.borrow_mut().pop();
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_record_and_filter() {
        let sink = TelemetrySink::new("driver", 16);
        sink.record(7, "queue_wait", 1000, 50);
        sink.record(7, "execute", 1050, 200);
        sink.record(9, "execute", 2000, 10);
        sink.mark(7, "progress:lanczos");
        assert_eq!(sink.len(), 4);
        let j7 = sink.spans_for(7);
        assert_eq!(j7.len(), 3);
        assert!(j7.iter().all(|s| s.source == "driver"));
        assert_eq!(j7[1].end_us(), 1250);
        assert_eq!(j7[2].dur_us, 0);
    }

    #[test]
    fn ring_evicts_oldest_and_counts_drops() {
        let sink = TelemetrySink::new("w0", 3);
        for i in 0..5u64 {
            sink.record(i, "s", i * 10, 1);
        }
        assert_eq!(sink.len(), 3);
        assert_eq!(sink.dropped(), 2);
        let spans = sink.snapshot();
        assert_eq!(spans[0].trace_id, 2); // 0 and 1 evicted
    }

    #[test]
    fn disabled_sink_records_nothing() {
        let sink = TelemetrySink::new("w0", 8);
        sink.set_enabled(false);
        sink.record(1, "s", 0, 1);
        {
            let _g = sink.span(1, "guarded");
        }
        assert!(sink.is_empty());
        sink.set_enabled(true);
        sink.mark(1, "s");
        assert_eq!(sink.len(), 1);
    }

    #[test]
    fn span_guard_measures() {
        let sink = TelemetrySink::new("client", 8);
        {
            let g = sink.span(3, "send");
            std::thread::sleep(std::time::Duration::from_millis(2));
            g.done();
        }
        let spans = sink.snapshot();
        assert_eq!(spans.len(), 1);
        assert!(spans[0].dur_us >= 1_000, "dur {}", spans[0].dur_us);
        assert!(spans[0].start_us > 0);
        assert_eq!(spans[0].name, "send");
    }

    #[test]
    fn source_retag_applies_to_new_spans() {
        let sink = TelemetrySink::new("w?", 8);
        sink.record(1, "a", 0, 1);
        sink.set_source("w3");
        sink.record(1, "b", 1, 1);
        let spans = sink.snapshot();
        assert_eq!(spans[0].source, "w?");
        assert_eq!(spans[1].source, "w3");
    }

    #[test]
    fn trace_ctx_nests_and_restores() {
        assert!(current_trace().is_none());
        {
            let _a = push_trace_ctx(5, "w0");
            assert_eq!(current_trace(), Some((5, "w0".into())));
            {
                let _b = push_trace_ctx(6, "w0");
                assert_eq!(current_trace().unwrap().0, 6);
            }
            assert_eq!(current_trace().unwrap().0, 5);
        }
        assert!(current_trace().is_none());
    }

    #[test]
    fn span_wire_roundtrip() {
        let s = SpanRecord {
            trace_id: 42,
            name: "compute".into(),
            source: "w1".into(),
            start_us: 1_700_000_000_000_000,
            dur_us: 12_345,
        };
        let mut w = Writer::new();
        s.encode_into(&mut w);
        let bytes = w.into_bytes();
        let got = SpanRecord::decode(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(got, s);
    }
}
