//! Standalone data-plane transfer helpers, shared by the driver-side ACI
//! (`AlchemistContext`) and sparklet *executors* — in the paper, each
//! Spark executor pushes its own partitions to the Alchemist workers
//! directly, so the routing/batching logic must be callable from any
//! thread holding only the worker address table and the matrix metadata.
//!
//! Since protocol v5 this is a pipelined, slab-oriented path:
//!
//! * the routing thread packs rows into per-owner slab batches (one index
//!   array + one contiguous value slab, no per-row allocations);
//! * full batches flow through bounded channels to sender threads, so
//!   routing/encode overlaps socket I/O across all owners (backpressure
//!   stalls are recorded per owner in [`TransferMetrics`]);
//! * each *lane*'s frames go through exactly one thread and one
//!   connection, preserving the per-connection ordering the `PutDone`
//!   barrier relies on;
//! * fetches run one thread per owner stream, merged through a
//!   mutex-protected sink that borrows each row straight out of the
//!   decoded slab.
//!
//! Protocol v9 adds the transfer plane v2 on top (all per-call knobs on
//! [`TransferOptions`]):
//!
//! * **pluggable transports** — connections are dialed through a
//!   [`Connector`] ([`crate::transport`]): plain TCP, the Unix-domain-
//!   socket fast path (auto-selected for co-located workers), or either
//!   one striped;
//! * **striping** — `stripes` lanes per owner. Pushes round-robin full
//!   batches over an owner's lanes and every lane runs its own `PutDone`
//!   barrier; fetches split each owner's row range into contiguous
//!   sub-ranges ([`stripe_ranges`]) and deliver them in stripe order, so
//!   the merged per-owner stream is index-ordered exactly like a single
//!   connection's;
//! * **wire compression** — a negotiated [`WireCodec`] applied inside the
//!   sender/fetch threads (`PutSlabZ`/`SlabBatchZ` frames), so the codec
//!   overlaps socket I/O; `comp_raw_bytes`/`comp_wire_bytes` record the
//!   achieved ratio and per-transport byte counters split the volume.
//!
//! Protocol v10 adds transfer *resume* (the `[retry]` config section):
//!
//! * **upload resume** — each sender lane keeps the batches sent since
//!   its last acknowledged `PutDone` (a mid-stream ack every
//!   [`ACK_EVERY`] batches bounds the window) and, on a transient socket
//!   failure, redials with capped exponential backoff and re-sends only
//!   that window over the fresh connection (`retry.slabs_resent` counts
//!   the replays). Redials degrade: configured transport first, plain
//!   TCP from the second retry on;
//! * **fetch resume** — workers stream a range in ascending global-index
//!   order, so a broken fetch re-requests exactly `[last_delivered+1,
//!   end)` on a fresh connection — no duplicates, no gaps;
//! * **fail-fast fan-in** — the first lane to exhaust its retries trips
//!   a shared abort latch; the router and every sibling sender observe
//!   it and bail out instead of blocking on a bounded channel (or
//!   finishing a doomed transfer), and `push_rows` surfaces that first
//!   error with its owner/stripe context.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Duration;

use crate::config::{RetryConfig, TransferConfig};
use crate::elemental::Layout;
use crate::metrics::{transfer_metrics, Timer, TransferMetrics};
use crate::protocol::{
    compress_slab, decompress_slab, frame, DataMsg, LayoutKind, MatrixMeta, WireCodec, WireRow,
    WorkerInfo, Writer,
};
use crate::transport::striped::stripe_ranges;
use crate::transport::{connector_for, Connector, Endpoint, Transport, TransportChoice};
use crate::{Error, Result};

/// Per-call tuning for the transfer helpers. Build one from the
/// `[transfer]` config section via [`TransferOptions::new`], or start from
/// `Default` (config defaults, 256 rows/frame, nodelay, slab wire format).
#[derive(Debug, Clone)]
pub struct TransferOptions {
    /// Rows per data-plane frame (paper behaviour = 1; see ablate_framing).
    pub batch_rows: usize,
    /// TCP_NODELAY on the data-plane sockets (both push and fetch).
    pub nodelay: bool,
    /// Sender threads for `push_rows`; lanes are multiplexed round-robin
    /// across them.
    pub sender_threads: usize,
    /// Target value bytes per frame; a batch flushes at this size even if
    /// `batch_rows` hasn't been reached.
    pub slab_bytes: usize,
    /// Bounded batches-in-flight per sender thread before the router
    /// blocks.
    pub channel_depth: usize,
    /// Use the v5 slab wire format. `false` keeps the v4 per-row
    /// `PutRows`/`RowBatch` frames for sessions negotiated at v4.
    pub use_slab: bool,
    /// How data-plane connections are dialed (`[transfer] transport`).
    pub transport: TransportChoice,
    /// Connections per owner (`[transfer] stripes`; 1 = classic).
    pub stripes: usize,
    /// Wire codec for slab frames. [`TransferOptions::new`] always starts
    /// at `None`; the ACI sets it only after the v9 `TransferCaps`
    /// exchange confirmed the server speaks the configured codec, so a
    /// bare `TransferOptions` can never emit frames a peer won't decode.
    pub codec: WireCodec,
    /// Retry/resume policy (`[retry]` config). `max_attempts <= 1`
    /// restores the pre-v10 behaviour: one try, fail hard, no resume
    /// window kept.
    pub retry: RetryConfig,
    /// Fault plane wrapped around every dialed connection (chaos
    /// tests/benches). `None` — the default — adds nothing to any path.
    pub fault: Option<std::sync::Arc<crate::fault::FaultPlane>>,
}

impl TransferOptions {
    pub fn new(cfg: &TransferConfig, batch_rows: usize, nodelay: bool, use_slab: bool) -> Self {
        TransferOptions {
            batch_rows,
            nodelay,
            sender_threads: cfg.sender_threads.max(1) as usize,
            slab_bytes: cfg.slab_bytes as usize,
            channel_depth: cfg.channel_depth.max(1) as usize,
            use_slab,
            transport: TransportChoice::parse(&cfg.transport).unwrap_or_default(),
            stripes: cfg.stripes.max(1) as usize,
            codec: WireCodec::None,
            retry: RetryConfig::default(),
            fault: None,
        }
    }

    /// True when slab frames should cross the wire compressed.
    fn compressed(&self) -> bool {
        self.use_slab && self.codec != WireCodec::None
    }
}

impl Default for TransferOptions {
    fn default() -> Self {
        TransferOptions::new(&TransferConfig::default(), 256, true, true)
    }
}

/// A worker's data-plane endpoint: its TCP address plus the UDS path it
/// advertised (empty for ≤ v8 servers and remote mesh peers).
pub fn worker_endpoint(w: &WorkerInfo) -> Endpoint {
    Endpoint { tcp_addr: w.data_addr.clone(), uds_addr: w.uds_addr.clone() }
}

/// Dial one worker's data plane with the configured transport — the
/// single-connection entry point (`finish_put`, ad-hoc control frames).
pub fn dial_worker(w: &WorkerInfo, opts: &TransferOptions) -> Result<Transport> {
    data_connector(opts).dial(&worker_endpoint(w))
}

/// Primary data-plane connector: the configured transport, wrapped by
/// the fault plane when one is installed.
fn data_connector(opts: &TransferOptions) -> Box<dyn Connector> {
    crate::fault::wrap_connector(connector_for(opts.transport, opts.nodelay), &opts.fault)
}

/// Connector for redial attempt `attempt` (count of failures so far):
/// the configured transport for the first retry, plain TCP from the
/// second on — the degradation ladder drops the UDS fast path in case
/// the fast path itself is what is broken. The fault wrapper stays on
/// every rung, so chaos schedules exercise redials too.
fn redial_connector(opts: &TransferOptions, attempt: u32) -> Box<dyn Connector> {
    let choice = if attempt >= 2 { TransportChoice::Tcp } else { opts.transport };
    crate::fault::wrap_connector(connector_for(choice, opts.nodelay), &opts.fault)
}

/// Mid-stream ack cadence (batches per lane between `PutDone` barriers)
/// when upload resume is active: bounds both the resend window and the
/// memory pinned by unacknowledged slabs (~`ACK_EVERY * slab_bytes`).
const ACK_EVERY: usize = 8;

/// Shared abort latch for one `push_rows` call. The first lane to fail
/// (after exhausting its retries) parks its error — with owner/stripe
/// context — here; the router and every sibling sender poll the latch
/// and bail out instead of completing a doomed transfer or blocking
/// forever on a bounded channel whose consumer is gone.
struct AbortState {
    failed: AtomicBool,
    first: Mutex<Option<Error>>,
}

impl AbortState {
    fn new() -> AbortState {
        AbortState { failed: AtomicBool::new(false), first: Mutex::new(None) }
    }

    fn record(&self, e: Error) {
        let mut g = self.first.lock().unwrap();
        if g.is_none() {
            *g = Some(e);
        }
        self.failed.store(true, Ordering::SeqCst);
    }

    fn is_failed(&self) -> bool {
        self.failed.load(Ordering::Relaxed)
    }

    fn take(&self) -> Option<Error> {
        self.first.lock().unwrap().take()
    }
}

/// One routed batch in flight between the router and a sender thread:
/// `indices[i]`'s row lives at `values[i*cols .. (i+1)*cols]`, bound for
/// lane `slot * stripes + stripe`.
struct RouteBatch {
    slot: usize,
    stripe: usize,
    indices: Vec<u64>,
    values: Vec<f64>,
}

impl RouteBatch {
    fn empty(slot: usize) -> RouteBatch {
        RouteBatch { slot, stripe: 0, indices: Vec::new(), values: Vec::new() }
    }
}

/// Resolve the data-plane endpoint of every owner slot up front (one
/// hash-map build instead of a linear `workers` scan per flush).
fn resolve_owner_endpoints(workers: &[WorkerInfo], owners: &[u32]) -> Result<Vec<Endpoint>> {
    let by_id: HashMap<u32, &WorkerInfo> = workers.iter().map(|w| (w.id, w)).collect();
    owners
        .iter()
        .map(|id| {
            by_id
                .get(id)
                .map(|w| worker_endpoint(w))
                .ok_or_else(|| Error::Server(format!("no address for worker {id}")))
        })
        .collect()
}

fn pipeline_closed() -> Error {
    Error::Server("transfer pipeline closed early (sender failed)".into())
}

/// Hand a full batch to its lane's sender thread, stalling (and timing
/// the stall) when that lane's pipeline is saturated. The stall is a
/// bounded poll, not a blocking `send`: it watches the abort latch so a
/// dead sibling sender can never leave the router wedged against a full
/// channel.
fn dispatch(
    txs: &[mpsc::SyncSender<RouteBatch>],
    owners: &[u32],
    stripes: usize,
    metrics: &TransferMetrics,
    abort: &AbortState,
    batch: RouteBatch,
) -> Result<()> {
    let owner = owners[batch.slot];
    let lane = batch.slot * stripes + batch.stripe;
    let tx = &txs[lane % txs.len()];
    match tx.try_send(batch) {
        Ok(()) => Ok(()),
        Err(mpsc::TrySendError::Full(batch)) => {
            let t = Timer::start();
            let mut batch = batch;
            let r = loop {
                if abort.is_failed() {
                    break Err(pipeline_closed());
                }
                match tx.try_send(batch) {
                    Ok(()) => break Ok(()),
                    Err(mpsc::TrySendError::Full(b)) => {
                        batch = b;
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => break Err(pipeline_closed()),
                }
            };
            metrics.phases.add(&format!("stall_w{owner}"), t.elapsed());
            r
        }
        Err(mpsc::TrySendError::Disconnected(_)) => Err(pipeline_closed()),
    }
}

/// Rebuild per-row `WireRow`s from a slab batch (the v4 compat path).
fn slab_to_rows(indices: Vec<u64>, values: Vec<f64>, cols: usize) -> Vec<WireRow> {
    indices
        .into_iter()
        .enumerate()
        .map(|(i, index)| WireRow { index, values: values[i * cols..(i + 1) * cols].to_vec() })
        .collect()
}

/// Per-transport byte split + compression accounting, tallied locally in
/// each worker thread and folded into the shared counters once at the
/// end (one relaxed add per handle, never on the per-frame path).
#[derive(Default)]
struct WireTally {
    tcp: u64,
    uds: u64,
    comp_raw: u64,
    comp_wire: u64,
}

impl WireTally {
    fn frame(&mut self, t: &Transport, n: u64) {
        match t.kind() {
            crate::transport::TransportKind::Tcp => self.tcp += n,
            crate::transport::TransportKind::Uds => self.uds += n,
        }
    }

    fn publish_sent(&self, metrics: &TransferMetrics) {
        metrics.tcp_bytes_sent.inc(self.tcp);
        metrics.uds_bytes_sent.inc(self.uds);
        metrics.comp_raw_bytes.inc(self.comp_raw);
        metrics.comp_wire_bytes.inc(self.comp_wire);
    }

    fn publish_recv(&self, metrics: &TransferMetrics) {
        metrics.tcp_bytes_recv.inc(self.tcp);
        metrics.uds_bytes_recv.inc(self.uds);
        metrics.comp_raw_bytes.inc(self.comp_raw);
        metrics.comp_wire_bytes.inc(self.comp_wire);
    }
}

/// Per-lane sender state: one connection plus the resume window — every
/// batch sent since the lane's last acknowledged `PutDone`. On a
/// transient failure the lane redials and re-sends exactly that window
/// (worker-side row stores are idempotent by row index, so replaying a
/// batch the worker already stored is harmless — and `rows_received`
/// counts distinct rows, so the transfer-complete check stays exact).
struct LaneState {
    slot: usize,
    stripe: usize,
    conn: Option<Transport>,
    unacked: Vec<RouteBatch>,
    /// Prefix of `unacked` already written to `conn`.
    sent: usize,
    /// High-water mark of `sent` since the last ack: sending a batch
    /// below it again is a resend (counted in `retry.slabs_resent`).
    high_water: usize,
    /// Redial attempts since the last successful ack.
    attempt: u32,
}

impl LaneState {
    fn new(slot: usize, stripe: usize) -> LaneState {
        LaneState {
            slot,
            stripe,
            conn: None,
            unacked: Vec::new(),
            sent: 0,
            high_water: 0,
            attempt: 0,
        }
    }

    fn acked(&mut self) {
        self.unacked.clear();
        self.sent = 0;
        self.high_water = 0;
        self.attempt = 0;
    }
}

/// Encode one batch as the negotiated frame shape and send it, returning
/// the framed byte count. The batch's buffers are moved into the frame
/// message and restored afterwards, so the caller keeps the batch for
/// the resume window without copying the slab.
fn encode_send(
    conn: &mut Transport,
    wbuf: &mut Writer,
    zbuf: &mut Vec<u8>,
    handle: u64,
    cols: u32,
    batch: &mut RouteBatch,
    opts: &TransferOptions,
    tally: &mut WireTally,
) -> Result<u64> {
    let msg = if opts.compressed() {
        compress_slab(opts.codec, &batch.indices, &batch.values, zbuf);
        tally.comp_raw += 8 * (batch.indices.len() + batch.values.len()) as u64;
        tally.comp_wire += zbuf.len() as u64;
        DataMsg::PutSlabZ {
            handle,
            codec: opts.codec.tag(),
            count: batch.indices.len() as u32,
            cols,
            payload: std::mem::take(zbuf),
        }
    } else if opts.use_slab {
        DataMsg::PutSlab {
            handle,
            indices: std::mem::take(&mut batch.indices),
            cols,
            values: std::mem::take(&mut batch.values),
        }
    } else {
        // v4 compat path: per-row frames. The clone keeps the batch for
        // the resume window; this shape never sees the hot path.
        DataMsg::PutRows {
            handle,
            rows: slab_to_rows(batch.indices.clone(), batch.values.clone(), cols as usize),
        }
    };
    let res = conn.send_frame(wbuf, |w| msg.encode_into(w)).map(|n| n as u64);
    match msg {
        DataMsg::PutSlabZ { payload, .. } => *zbuf = payload, // reclaim the buffer
        DataMsg::PutSlab { indices, values, .. } => {
            batch.indices = indices;
            batch.values = values;
        }
        _ => {}
    }
    if let Ok(n) = res {
        tally.frame(conn, n);
    }
    res
}

/// Bring one lane up to date: dial if needed (re-sending the resume
/// window on a fresh connection), write every pending batch, and — when
/// `want_ack` — run the `PutDone` barrier. Transient socket failures
/// retry with capped exponential backoff up to `retry.max_attempts`
/// total tries; typed worker/protocol errors fail immediately.
#[allow(clippy::too_many_arguments)]
fn flush_lane(
    lane: &mut LaneState,
    ep: &Endpoint,
    handle: u64,
    cols: u32,
    opts: &TransferOptions,
    want_ack: bool,
    wbuf: &mut Writer,
    zbuf: &mut Vec<u8>,
    tally: &mut WireTally,
    frames: &mut u64,
    bytes: &mut u64,
) -> Result<()> {
    let metrics = transfer_metrics();
    let max_attempts = opts.retry.max_attempts.max(1);
    loop {
        let step = (|| -> Result<()> {
            if lane.conn.is_none() {
                let connector = if lane.attempt == 0 {
                    data_connector(opts)
                } else {
                    redial_connector(opts, lane.attempt)
                };
                lane.conn = Some(connector.dial(ep)?);
            }
            let conn = lane.conn.as_mut().unwrap();
            while lane.sent < lane.unacked.len() {
                let resend = lane.sent < lane.high_water;
                let n = encode_send(
                    conn,
                    wbuf,
                    zbuf,
                    handle,
                    cols,
                    &mut lane.unacked[lane.sent],
                    opts,
                    tally,
                )?;
                *bytes += n;
                *frames += 1;
                lane.sent += 1;
                if resend {
                    metrics.slabs_resent.inc(1);
                } else {
                    lane.high_water = lane.sent;
                }
            }
            if want_ack {
                let done = DataMsg::PutDone { handle };
                conn.send_frame(wbuf, |w| done.encode_into(w))?;
                match DataMsg::decode(&frame::read_frame(conn)?)? {
                    DataMsg::PutComplete { .. } => {}
                    DataMsg::Err { message } => return Err(Error::Server(message)),
                    other => return Err(Error::Protocol(format!("unexpected {other:?}"))),
                }
                lane.acked();
            }
            Ok(())
        })();
        match step {
            Ok(()) => return Ok(()),
            Err(e) if e.is_transient_io() && lane.attempt + 1 < max_attempts => {
                // The stream is dead: everything written to it since the
                // last ack must go again on the next connection.
                lane.conn = None;
                lane.sent = 0;
                lane.attempt += 1;
                metrics.retry_attempts.inc(1);
                std::thread::sleep(crate::fault::retry_backoff(
                    lane.attempt,
                    opts.retry.backoff_base_ms,
                    opts.retry.backoff_cap_ms,
                    handle ^ (lane.slot * 31 + lane.stripe) as u64,
                ));
            }
            Err(e) => {
                if e.is_transient_io() {
                    metrics.retry_exhausted.inc(1);
                }
                return Err(e);
            }
        }
    }
}

/// First-failure context: which owner and stripe the failing lane served.
fn lane_error(owners: &[u32], lane: &LaneState, e: Error) -> Error {
    Error::Server(format!(
        "upload lane to worker {} (stripe {}) failed after {} attempt(s): {e}",
        owners[lane.slot],
        lane.stripe,
        lane.attempt + 1
    ))
}

/// One sender thread: drains its bounded channel, lazily dialing one
/// connection (and one resume window) per *lane* it serves, then runs
/// the per-connection `PutDone` barrier when the channel closes.
///
/// The barrier matters: a worker processes frames on one connection in
/// order, so acking a `PutDone` here guarantees every row this call sent
/// has been stored before `push_rows` returns. Without it, a subsequent
/// `finish_put` on a *fresh* connection could overtake in-flight rows
/// (TCP orders within, not across, connections). With striping the same
/// invariant holds per lane — every lane is drained and acked, so the
/// union of all lanes' rows is durable when `push_rows` returns. Resume
/// preserves it too: a redial replays the whole unacknowledged window in
/// order on one fresh connection before the next ack.
#[allow(clippy::too_many_arguments)]
fn run_sender(
    rx: mpsc::Receiver<RouteBatch>,
    endpoints: &[Endpoint],
    owners: &[u32],
    stripes: usize,
    handle: u64,
    cols: u32,
    opts: &TransferOptions,
    abort: &AbortState,
) -> Result<u64> {
    let mut lanes: HashMap<usize, LaneState> = HashMap::new();
    let mut wbuf = Writer::new();
    let mut zbuf: Vec<u8> = Vec::new();
    let mut frames = 0u64;
    let mut bytes = 0u64;
    let mut tally = WireTally::default();
    let resume = opts.retry.max_attempts > 1;
    let mut failed = false;
    while let Ok(batch) = rx.recv() {
        if failed || abort.is_failed() {
            continue; // drain, so the router never blocks on a doomed pipeline
        }
        let lane_id = batch.slot * stripes + batch.stripe;
        let lane =
            lanes.entry(lane_id).or_insert_with(|| LaneState::new(batch.slot, batch.stripe));
        lane.unacked.push(batch);
        let want_ack = resume && lane.unacked.len() >= ACK_EVERY;
        match flush_lane(
            lane,
            &endpoints[lane.slot],
            handle,
            cols,
            opts,
            want_ack,
            &mut wbuf,
            &mut zbuf,
            &mut tally,
            &mut frames,
            &mut bytes,
        ) {
            Ok(()) => {
                if !resume {
                    // no resume window to keep: the batch is on the wire
                    lane.unacked.clear();
                    lane.sent = 0;
                    lane.high_water = 0;
                }
            }
            Err(e) => {
                abort.record(lane_error(owners, lane, e));
                failed = true;
            }
        }
    }
    if !failed && !abort.is_failed() {
        // Final barrier: drain and ack every lane, redialing lanes whose
        // connection died with batches still unacknowledged.
        for lane in lanes.values_mut() {
            if lane.conn.is_none() && lane.unacked.is_empty() {
                continue;
            }
            if let Err(e) = flush_lane(
                lane,
                &endpoints[lane.slot],
                handle,
                cols,
                opts,
                true,
                &mut wbuf,
                &mut zbuf,
                &mut tally,
                &mut frames,
                &mut bytes,
            ) {
                abort.record(lane_error(owners, lane, e));
                failed = true;
                break;
            }
        }
    }
    // Pre-registered handles (one relaxed atomic add each), not the
    // string-keyed legacy view — this runs once per sender thread but the
    // same handles back the per-frame counters elsewhere.
    let metrics = transfer_metrics();
    metrics.bytes_sent.inc(bytes);
    metrics.frames_sent.inc(frames);
    tally.publish_sent(metrics);
    if failed {
        Err(pipeline_closed())
    } else {
        Ok(frames)
    }
}

/// Route and push a set of rows to the owning Alchemist workers.
/// `workers` must contain an entry for every owner id in `meta`, and each
/// row must be exactly `meta.cols` wide (validated before it is shipped).
/// Callable concurrently from many threads with disjoint row sets.
/// Returns (rows_sent, frames_sent).
pub fn push_rows<V: AsRef<[f64]>>(
    workers: &[WorkerInfo],
    meta: &MatrixMeta,
    rows: impl Iterator<Item = (u64, V)>,
    opts: &TransferOptions,
) -> Result<(u64, u64)> {
    if meta.layout.kind == LayoutKind::Replicated {
        // Routing a row to its "owner" would populate one replica only;
        // replicated matrices are produced by routines, never uploaded.
        return Err(Error::Shape(
            "cannot push rows to a Replicated matrix (routine outputs only)".into(),
        ));
    }
    let layout = Layout::from_desc(&meta.layout, meta.rows)?;
    let owners = &meta.layout.owners;
    let cols = meta.cols as usize;
    let endpoints = resolve_owner_endpoints(workers, owners)?;
    let abort = AbortState::new();

    let stripes = opts.stripes.max(1);
    let lanes = owners.len().max(1) * stripes;
    let threads = opts.sender_threads.max(1).min(lanes);
    let batch_rows = opts.batch_rows.max(1);
    // flush a batch once its value slab reaches slab_bytes (but always
    // accept at least one row per batch, however wide)
    let value_cap = (opts.slab_bytes / 8).max(cols.max(1));

    let metrics = transfer_metrics();
    let mut rows_sent = 0u64;

    let frames_sent = std::thread::scope(|scope| -> Result<u64> {
        let mut txs: Vec<mpsc::SyncSender<RouteBatch>> = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let (tx, rx) = mpsc::sync_channel::<RouteBatch>(opts.channel_depth.max(1));
            txs.push(tx);
            let endpoints = &endpoints;
            let abort = &abort;
            handles.push(scope.spawn(move || {
                run_sender(rx, endpoints, owners, stripes, meta.handle, cols as u32, opts, abort)
            }));
        }

        let mut pending: Vec<RouteBatch> = (0..owners.len()).map(RouteBatch::empty).collect();
        // next stripe per owner slot — full batches round-robin over the
        // owner's lanes so a fat pipe is filled by `stripes` connections
        let mut rr = vec![0usize; owners.len()];
        let mut flush = |batch: &mut RouteBatch, rr: &mut [usize]| -> Result<()> {
            let slot = batch.slot;
            let mut full = std::mem::replace(batch, RouteBatch::empty(slot));
            full.stripe = rr[slot];
            rr[slot] = (rr[slot] + 1) % stripes;
            dispatch(&txs, owners, stripes, metrics, &abort, full)
        };
        let mut route_err: Option<Error> = None;
        for (index, values) in rows {
            if abort.is_failed() {
                route_err = Some(pipeline_closed());
                break;
            }
            let values = values.as_ref();
            if index >= meta.rows {
                route_err = Some(Error::Shape(format!(
                    "row {index} out of range ({} rows)",
                    meta.rows
                )));
                break;
            }
            if values.len() != cols {
                route_err = Some(Error::Shape(format!(
                    "row {index} has {} values, matrix has {cols} cols",
                    values.len()
                )));
                break;
            }
            let slot = layout.owner_slot(index) as usize;
            let b = &mut pending[slot];
            b.indices.push(index);
            b.values.extend_from_slice(values);
            rows_sent += 1;
            if b.indices.len() >= batch_rows || b.values.len() >= value_cap {
                if let Err(e) = flush(b, &mut rr) {
                    route_err = Some(e);
                    break;
                }
            }
        }
        if route_err.is_none() {
            for slot in 0..owners.len() {
                if pending[slot].indices.is_empty() {
                    continue;
                }
                if let Err(e) = flush(&mut pending[slot], &mut rr) {
                    route_err = Some(e);
                    break;
                }
            }
        }
        // close the channels so senders drain and run their PutDone barrier
        drop(flush);
        drop(txs);

        let mut frames = 0u64;
        let mut sender_err: Option<Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(f)) => frames += f,
                Ok(Err(e)) => sender_err = sender_err.or(Some(e)),
                Err(_) => {
                    sender_err =
                        sender_err.or_else(|| Some(Error::Server("sender thread panicked".into())))
                }
            }
        }
        // The abort latch holds the chronologically-first lane failure
        // (with owner/stripe context); it is the root cause of every
        // routing-side disconnect and of the senders' marker errors, so
        // it wins over both.
        match abort.take().or(sender_err).or(route_err) {
            Some(e) => Err(e),
            None => Ok(frames),
        }
    })?;

    metrics.rows_sent.inc(rows_sent);
    Ok((rows_sent, frames_sent))
}

/// Stream one owner's rows for `[start, end)` with resume, feeding every
/// decoded frame to `feed(indices, row-major values)`. Transient socket
/// failures redial with backoff (configured transport first, plain TCP
/// from the second retry) and re-request only the not-yet-delivered
/// tail: workers stream a range in ascending global-index order, so
/// "resume after the last delivered index" is exact — no duplicates, no
/// gaps. Typed worker/protocol/sink errors fail immediately.
fn fetch_range<F: FnMut(&[u64], &[f64]) -> Result<()>>(
    ep: &Endpoint,
    meta: &MatrixMeta,
    start: u64,
    end: u64,
    opts: &TransferOptions,
    mut feed: F,
) -> Result<u64> {
    let metrics = transfer_metrics();
    let max_attempts = opts.retry.max_attempts.max(1);
    let mut next_start = start;
    let mut seen = 0u64;
    let mut attempt = 0u32;
    loop {
        let connector =
            if attempt == 0 { data_connector(opts) } else { redial_connector(opts, attempt) };
        let r = {
            let next_start = &mut next_start;
            let seen = &mut seen;
            let feed = &mut feed;
            fetch_range_once(
                connector.as_ref(),
                ep,
                meta,
                *next_start,
                end,
                opts,
                |indices, values| {
                    feed(indices, values)?;
                    if let Some(&last) = indices.last() {
                        *next_start = last + 1;
                        *seen += indices.len() as u64;
                    }
                    Ok(())
                },
            )
        };
        match r {
            Ok(()) => return Ok(seen),
            Err(e) if e.is_transient_io() && attempt + 1 < max_attempts => {
                attempt += 1;
                metrics.retry_attempts.inc(1);
                std::thread::sleep(crate::fault::retry_backoff(
                    attempt,
                    opts.retry.backoff_base_ms,
                    opts.retry.backoff_cap_ms,
                    meta.handle ^ start,
                ));
            }
            Err(e) => {
                if e.is_transient_io() {
                    metrics.retry_exhausted.inc(1);
                }
                return Err(e);
            }
        }
    }
}

/// One fetch connection's lifetime: request `[start, end)` and stream
/// reply frames to `feed` (borrowed straight out of the receive
/// buffers). Handles all three reply shapes: plain slabs, compressed
/// slabs (decompressed into reusable buffers here, so the codec runs on
/// this fetch thread), and v4 row batches.
fn fetch_range_once<F: FnMut(&[u64], &[f64]) -> Result<()>>(
    connector: &dyn Connector,
    ep: &Endpoint,
    meta: &MatrixMeta,
    start: u64,
    end: u64,
    opts: &TransferOptions,
    mut feed: F,
) -> Result<()> {
    let mut t = connector.dial(ep)?;
    let handle = meta.handle;
    let req = if opts.compressed() {
        DataMsg::GetRowsSlabZ { handle, start, end, codec: opts.codec.tag() }
    } else if opts.use_slab {
        DataMsg::GetRowsSlab { handle, start, end }
    } else {
        DataMsg::GetRows { handle, start, end }
    };
    let mut wbuf = Writer::new();
    t.send_frame(&mut wbuf, |w| req.encode_into(w))?;
    let mut buf = Vec::new();
    let mut ibuf: Vec<u64> = Vec::new();
    let mut vbuf: Vec<f64> = Vec::new();
    let mut frames = 0u64;
    let mut bytes = 0u64;
    let mut tally = WireTally::default();
    let want_cols = meta.cols;
    let check_cols = |cols: u32| -> Result<()> {
        if u64::from(cols) != want_cols {
            return Err(Error::Protocol(format!(
                "fetched slab is {cols} wide, matrix has {want_cols} cols"
            )));
        }
        Ok(())
    };
    loop {
        let n = t.recv_frame_into(&mut buf)?;
        frames += 1;
        let framed = n as u64 + 4; // + header, mirroring the send-side count
        bytes += framed;
        tally.frame(&t, framed);
        match DataMsg::decode(&buf)? {
            DataMsg::SlabBatchZ { codec, count, cols, payload, .. } => {
                // the worker echoes the requested codec; the payload is
                // self-describing, so decode doesn't need it — but a
                // mismatch means crossed streams
                if codec != opts.codec.tag() {
                    return Err(Error::Protocol(format!(
                        "SlabBatchZ codec {codec} != requested {}",
                        opts.codec.tag()
                    )));
                }
                check_cols(cols)?;
                decompress_slab(&payload, count as usize, cols as usize, &mut ibuf, &mut vbuf)?;
                tally.comp_raw += 8 * (ibuf.len() + vbuf.len()) as u64;
                tally.comp_wire += payload.len() as u64;
                feed(&ibuf, &vbuf)?;
            }
            DataMsg::SlabBatch { indices, cols, values, .. } => {
                check_cols(cols)?;
                feed(&indices, &values)?;
            }
            DataMsg::RowBatch { rows, .. } => {
                for row in rows {
                    check_cols(row.values.len() as u32)?;
                    feed(&[row.index], &row.values)?;
                }
            }
            DataMsg::GetDone { .. } => break,
            DataMsg::Err { message } => return Err(Error::Server(message)),
            other => return Err(Error::Protocol(format!("unexpected {other:?}"))),
        }
    }
    let metrics = transfer_metrics();
    metrics.bytes_recv.inc(bytes);
    metrics.frames_recv.inc(frames);
    tally.publish_recv(metrics);
    Ok(())
}

/// Fetch one owner's rows on a single connection, feeding each decoded
/// row to the shared sink (one lock per frame, not per row).
fn fetch_one<F: FnMut(u64, &[f64]) -> Result<()>>(
    ep: &Endpoint,
    meta: &MatrixMeta,
    start: u64,
    end: u64,
    opts: &TransferOptions,
    sink: &Mutex<F>,
) -> Result<u64> {
    let cols = meta.cols as usize;
    fetch_range(ep, meta, start, end, opts, |indices, values| {
        let mut guard = sink.lock().unwrap();
        let f = &mut *guard;
        for (i, &index) in indices.iter().enumerate() {
            f(index, &values[i * cols..(i + 1) * cols])?;
        }
        Ok(())
    })
}

/// Fetch one owner's rows over `stripes` connections: the range is split
/// into contiguous sub-ranges, each lane buffers its sub-range, and the
/// buffers are delivered to the sink in stripe order. Workers stream a
/// range in ascending global-index order, so the merged per-owner stream
/// is deterministic and index-sorted — exactly the row sequence a single
/// connection would have produced.
fn fetch_one_striped<F: FnMut(u64, &[f64]) -> Result<()>>(
    ep: &Endpoint,
    meta: &MatrixMeta,
    start: u64,
    end: u64,
    opts: &TransferOptions,
    sink: &Mutex<F>,
) -> Result<u64> {
    let ranges = stripe_ranges(start, end, opts.stripes);
    let bufs: Vec<Result<(Vec<u64>, Vec<f64>)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .iter()
            .map(|&(s, e)| {
                scope.spawn(move || -> Result<(Vec<u64>, Vec<f64>)> {
                    let mut idx: Vec<u64> = Vec::new();
                    let mut vals: Vec<f64> = Vec::new();
                    // Each stripe resumes its own sub-range; a stripe
                    // that falls back to TCP degrades only itself.
                    fetch_range(ep, meta, s, e, opts, |indices, values| {
                        idx.extend_from_slice(indices);
                        vals.extend_from_slice(values);
                        Ok(())
                    })?;
                    Ok((idx, vals))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(Error::Server("fetch stripe thread panicked".into())))
            })
            .collect()
    });
    let cols = meta.cols as usize;
    let mut seen = 0u64;
    let mut guard = sink.lock().unwrap();
    let f = &mut *guard;
    for r in bufs {
        let (idx, vals) = r?;
        for (i, &index) in idx.iter().enumerate() {
            f(index, &vals[i * cols..(i + 1) * cols])?;
            seen += 1;
        }
    }
    Ok(seen)
}

/// Fetch rows `[start, end)` of an Alchemist matrix, calling `sink` for
/// each row received. All owners are fetched in parallel (one thread per
/// owner stream) and merged through a mutex around the sink, so rows
/// arrive unordered across owners; each row's values are borrowed from
/// the receive slab (copy out if you need to keep them). A `Replicated`
/// matrix is read from its first owner only — every owner holds the full
/// matrix, so fanning out would both duplicate rows and bother p-1
/// workers for nothing.
pub fn fetch_rows<F>(
    workers: &[WorkerInfo],
    meta: &MatrixMeta,
    start: u64,
    end: u64,
    opts: &TransferOptions,
    sink: F,
) -> Result<u64>
where
    F: FnMut(u64, &[f64]) -> Result<()> + Send,
{
    let mut endpoints = resolve_owner_endpoints(workers, &meta.layout.owners)?;
    if meta.layout.kind == LayoutKind::Replicated {
        endpoints.truncate(1);
    }
    let striped = opts.stripes > 1;
    let sink = Mutex::new(sink);
    let results: Vec<Result<u64>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(endpoints.len());
        for ep in &endpoints {
            let sink = &sink;
            handles.push(scope.spawn(move || {
                if striped {
                    fetch_one_striped(ep, meta, start, end, opts, sink)
                } else {
                    fetch_one(ep, meta, start, end, opts, sink)
                }
            }));
        }
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| Err(Error::Server("fetch thread panicked".into())))
            })
            .collect()
    });
    let mut seen = 0u64;
    for r in results {
        seen += r?;
    }
    transfer_metrics().rows_recv.inc(seen);
    Ok(seen)
}
