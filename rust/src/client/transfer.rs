//! Standalone data-plane transfer helpers, shared by the driver-side ACI
//! (`AlchemistContext`) and sparklet *executors* — in the paper, each
//! Spark executor pushes its own partitions to the Alchemist workers
//! directly, so the routing/batching logic must be callable from any
//! thread holding only the worker address table and the matrix metadata.
//!
//! Since protocol v5 this is a pipelined, slab-oriented path:
//!
//! * the routing thread packs rows into per-owner slab batches (one index
//!   array + one contiguous value slab, no per-row allocations);
//! * full batches flow through bounded channels to sender threads, so
//!   routing/encode overlaps socket I/O across all owners (backpressure
//!   stalls are recorded per owner in [`TransferMetrics`]);
//! * each owner's frames go through exactly one thread and one
//!   connection, preserving the per-connection ordering the `PutDone`
//!   barrier relies on;
//! * fetches run one thread per owner, merged through a mutex-protected
//!   sink that borrows each row straight out of the decoded slab.

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::Mutex;

use crate::config::TransferConfig;
use crate::elemental::Layout;
use crate::metrics::{transfer_metrics, Timer, TransferMetrics};
use crate::protocol::{frame, DataMsg, LayoutKind, MatrixMeta, WireRow, WorkerInfo, Writer};
use crate::{Error, Result};

/// Per-call tuning for the transfer helpers. Build one from the
/// `[transfer]` config section via [`TransferOptions::new`], or start from
/// `Default` (config defaults, 256 rows/frame, nodelay, slab wire format).
#[derive(Debug, Clone)]
pub struct TransferOptions {
    /// Rows per data-plane frame (paper behaviour = 1; see ablate_framing).
    pub batch_rows: usize,
    /// TCP_NODELAY on the data-plane sockets (both push and fetch).
    pub nodelay: bool,
    /// Sender threads for `push_rows`; owners are multiplexed round-robin
    /// across them.
    pub sender_threads: usize,
    /// Target value bytes per frame; a batch flushes at this size even if
    /// `batch_rows` hasn't been reached.
    pub slab_bytes: usize,
    /// Bounded batches-in-flight per sender thread before the router
    /// blocks.
    pub channel_depth: usize,
    /// Use the v5 slab wire format. `false` keeps the v4 per-row
    /// `PutRows`/`RowBatch` frames for sessions negotiated at v4.
    pub use_slab: bool,
}

impl TransferOptions {
    pub fn new(cfg: &TransferConfig, batch_rows: usize, nodelay: bool, use_slab: bool) -> Self {
        TransferOptions {
            batch_rows,
            nodelay,
            sender_threads: cfg.sender_threads.max(1) as usize,
            slab_bytes: cfg.slab_bytes as usize,
            channel_depth: cfg.channel_depth.max(1) as usize,
            use_slab,
        }
    }
}

impl Default for TransferOptions {
    fn default() -> Self {
        TransferOptions::new(&TransferConfig::default(), 256, true, true)
    }
}

/// One routed batch in flight between the router and a sender thread:
/// `indices[i]`'s row lives at `values[i*cols .. (i+1)*cols]`.
struct RouteBatch {
    slot: usize,
    indices: Vec<u64>,
    values: Vec<f64>,
}

impl RouteBatch {
    fn empty(slot: usize) -> RouteBatch {
        RouteBatch { slot, indices: Vec::new(), values: Vec::new() }
    }
}

/// Resolve the data-plane address of every owner slot up front (one
/// hash-map build instead of a linear `workers` scan per flush).
fn resolve_owner_addrs(workers: &[WorkerInfo], owners: &[u32]) -> Result<Vec<String>> {
    let by_id: HashMap<u32, &WorkerInfo> = workers.iter().map(|w| (w.id, w)).collect();
    owners
        .iter()
        .map(|id| {
            by_id
                .get(id)
                .map(|w| w.data_addr.clone())
                .ok_or_else(|| Error::Server(format!("no address for worker {id}")))
        })
        .collect()
}

fn pipeline_closed() -> Error {
    Error::Server("transfer pipeline closed early (sender failed)".into())
}

/// Hand a full batch to its owner's sender thread, blocking (and timing
/// the stall) when that owner's pipeline is saturated.
fn dispatch(
    txs: &[mpsc::SyncSender<RouteBatch>],
    owners: &[u32],
    metrics: &TransferMetrics,
    batch: RouteBatch,
) -> Result<()> {
    let owner = owners[batch.slot];
    let tx = &txs[batch.slot % txs.len()];
    match tx.try_send(batch) {
        Ok(()) => Ok(()),
        Err(mpsc::TrySendError::Full(batch)) => {
            let t = Timer::start();
            let r = tx.send(batch).map_err(|_| pipeline_closed());
            metrics.phases.add(&format!("stall_w{owner}"), t.elapsed());
            r
        }
        Err(mpsc::TrySendError::Disconnected(_)) => Err(pipeline_closed()),
    }
}

/// Rebuild per-row `WireRow`s from a slab batch (the v4 compat path).
fn slab_to_rows(indices: Vec<u64>, values: Vec<f64>, cols: usize) -> Vec<WireRow> {
    indices
        .into_iter()
        .enumerate()
        .map(|(i, index)| WireRow { index, values: values[i * cols..(i + 1) * cols].to_vec() })
        .collect()
}

/// One sender thread: drains its bounded channel, lazily opening one
/// connection (and one reusable encode buffer) per owner slot it serves,
/// then runs the per-connection `PutDone` barrier when the channel closes.
///
/// The barrier matters: a worker processes frames on one connection in
/// order, so acking a `PutDone` here guarantees every row this call sent
/// has been stored before `push_rows` returns. Without it, a subsequent
/// `finish_put` on a *fresh* connection could overtake in-flight rows
/// (TCP orders within, not across, connections).
fn run_sender(
    rx: mpsc::Receiver<RouteBatch>,
    slot_addrs: &[String],
    handle: u64,
    cols: u32,
    opts: &TransferOptions,
) -> Result<u64> {
    let mut conns: HashMap<usize, TcpStream> = HashMap::new();
    let mut wbuf = Writer::new();
    let mut frames = 0u64;
    let mut bytes = 0u64;
    while let Ok(batch) = rx.recv() {
        let slot = batch.slot;
        if !conns.contains_key(&slot) {
            let s = TcpStream::connect(&slot_addrs[slot])?;
            if opts.nodelay {
                s.set_nodelay(true)?;
            }
            conns.insert(slot, s);
        }
        let conn = conns.get_mut(&slot).unwrap();
        let msg = if opts.use_slab {
            DataMsg::PutSlab { handle, indices: batch.indices, cols, values: batch.values }
        } else {
            DataMsg::PutRows {
                handle,
                rows: slab_to_rows(batch.indices, batch.values, cols as usize),
            }
        };
        bytes += frame::write_frame_with(conn, &mut wbuf, |w| msg.encode_into(w))? as u64;
        frames += 1;
    }
    for conn in conns.values_mut() {
        let done = DataMsg::PutDone { handle };
        frame::write_frame_with(conn, &mut wbuf, |w| done.encode_into(w))?;
        match DataMsg::decode(&frame::read_frame(conn)?)? {
            DataMsg::PutComplete { .. } => {}
            DataMsg::Err { message } => return Err(Error::Server(message)),
            other => return Err(Error::Protocol(format!("unexpected {other:?}"))),
        }
    }
    // Pre-registered handles (one relaxed atomic add each), not the
    // string-keyed legacy view — this runs once per sender thread but the
    // same handles back the per-frame counters elsewhere.
    let metrics = transfer_metrics();
    metrics.bytes_sent.inc(bytes);
    metrics.frames_sent.inc(frames);
    Ok(frames)
}

/// Route and push a set of rows to the owning Alchemist workers.
/// `workers` must contain an entry for every owner id in `meta`, and each
/// row must be exactly `meta.cols` wide (validated before it is shipped).
/// Callable concurrently from many threads with disjoint row sets.
/// Returns (rows_sent, frames_sent).
pub fn push_rows<V: AsRef<[f64]>>(
    workers: &[WorkerInfo],
    meta: &MatrixMeta,
    rows: impl Iterator<Item = (u64, V)>,
    opts: &TransferOptions,
) -> Result<(u64, u64)> {
    if meta.layout.kind == LayoutKind::Replicated {
        // Routing a row to its "owner" would populate one replica only;
        // replicated matrices are produced by routines, never uploaded.
        return Err(Error::Shape(
            "cannot push rows to a Replicated matrix (routine outputs only)".into(),
        ));
    }
    let layout = Layout::from_desc(&meta.layout, meta.rows)?;
    let owners = &meta.layout.owners;
    let cols = meta.cols as usize;
    let slot_addrs = resolve_owner_addrs(workers, owners)?;

    let threads = opts.sender_threads.max(1).min(owners.len().max(1));
    let batch_rows = opts.batch_rows.max(1);
    // flush a batch once its value slab reaches slab_bytes (but always
    // accept at least one row per batch, however wide)
    let value_cap = (opts.slab_bytes / 8).max(cols.max(1));

    let metrics = transfer_metrics();
    let mut rows_sent = 0u64;

    let frames_sent = std::thread::scope(|scope| -> Result<u64> {
        let mut txs: Vec<mpsc::SyncSender<RouteBatch>> = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let (tx, rx) = mpsc::sync_channel::<RouteBatch>(opts.channel_depth.max(1));
            txs.push(tx);
            let slot_addrs = &slot_addrs;
            handles.push(
                scope.spawn(move || run_sender(rx, slot_addrs, meta.handle, cols as u32, opts)),
            );
        }

        let mut pending: Vec<RouteBatch> = (0..owners.len()).map(RouteBatch::empty).collect();
        let mut route_err: Option<Error> = None;
        for (index, values) in rows {
            let values = values.as_ref();
            if index >= meta.rows {
                route_err = Some(Error::Shape(format!(
                    "row {index} out of range ({} rows)",
                    meta.rows
                )));
                break;
            }
            if values.len() != cols {
                route_err = Some(Error::Shape(format!(
                    "row {index} has {} values, matrix has {cols} cols",
                    values.len()
                )));
                break;
            }
            let slot = layout.owner_slot(index) as usize;
            let b = &mut pending[slot];
            b.indices.push(index);
            b.values.extend_from_slice(values);
            rows_sent += 1;
            if b.indices.len() >= batch_rows || b.values.len() >= value_cap {
                let full = std::mem::replace(b, RouteBatch::empty(slot));
                if let Err(e) = dispatch(&txs, owners, metrics, full) {
                    route_err = Some(e);
                    break;
                }
            }
        }
        if route_err.is_none() {
            for slot in 0..owners.len() {
                let b = std::mem::replace(&mut pending[slot], RouteBatch::empty(slot));
                if b.indices.is_empty() {
                    continue;
                }
                if let Err(e) = dispatch(&txs, owners, metrics, b) {
                    route_err = Some(e);
                    break;
                }
            }
        }
        // close the channels so senders drain and run their PutDone barrier
        drop(txs);

        let mut frames = 0u64;
        let mut sender_err: Option<Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(f)) => frames += f,
                Ok(Err(e)) => sender_err = sender_err.or(Some(e)),
                Err(_) => {
                    sender_err =
                        sender_err.or_else(|| Some(Error::Server("sender thread panicked".into())))
                }
            }
        }
        // a sender failure is the root cause of any routing-side
        // disconnect error, so it wins
        match sender_err.or(route_err) {
            Some(e) => Err(e),
            None => Ok(frames),
        }
    })?;

    metrics.rows_sent.inc(rows_sent);
    Ok((rows_sent, frames_sent))
}

/// Fetch one owner's rows, feeding each decoded row (borrowed straight
/// from the frame's slab) to the shared sink.
fn fetch_one<F: FnMut(u64, &[f64]) -> Result<()>>(
    addr: &str,
    meta: &MatrixMeta,
    start: u64,
    end: u64,
    opts: &TransferOptions,
    sink: &Mutex<F>,
) -> Result<u64> {
    let mut s = TcpStream::connect(addr)?;
    if opts.nodelay {
        s.set_nodelay(true)?;
    }
    let handle = meta.handle;
    let req = if opts.use_slab {
        DataMsg::GetRowsSlab { handle, start, end }
    } else {
        DataMsg::GetRows { handle, start, end }
    };
    frame::write_frame(&mut s, &req.encode())?;
    let mut buf = Vec::new();
    let mut seen = 0u64;
    let mut frames = 0u64;
    let mut bytes = 0u64;
    loop {
        let n = frame::read_frame_into(&mut s, &mut buf)?;
        frames += 1;
        bytes += n as u64 + 4; // + header, mirroring the send-side count
        match DataMsg::decode(&buf)? {
            DataMsg::SlabBatch { indices, cols, values, .. } => {
                let cols = cols as usize;
                let mut guard = sink.lock().unwrap();
                let f = &mut *guard;
                for (i, &index) in indices.iter().enumerate() {
                    f(index, &values[i * cols..(i + 1) * cols])?;
                    seen += 1;
                }
            }
            DataMsg::RowBatch { rows, .. } => {
                let mut guard = sink.lock().unwrap();
                let f = &mut *guard;
                for row in rows {
                    f(row.index, &row.values)?;
                    seen += 1;
                }
            }
            DataMsg::GetDone { .. } => break,
            DataMsg::Err { message } => return Err(Error::Server(message)),
            other => return Err(Error::Protocol(format!("unexpected {other:?}"))),
        }
    }
    let metrics = transfer_metrics();
    metrics.bytes_recv.inc(bytes);
    metrics.frames_recv.inc(frames);
    Ok(seen)
}

/// Fetch rows `[start, end)` of an Alchemist matrix, calling `sink` for
/// each row received. All owners are fetched in parallel (one thread per
/// owner stream) and merged through a mutex around the sink, so rows
/// arrive unordered across owners; each row's values are borrowed from
/// the receive slab (copy out if you need to keep them). A `Replicated`
/// matrix is read from its first owner only — every owner holds the full
/// matrix, so fanning out would both duplicate rows and bother p-1
/// workers for nothing.
pub fn fetch_rows<F>(
    workers: &[WorkerInfo],
    meta: &MatrixMeta,
    start: u64,
    end: u64,
    opts: &TransferOptions,
    sink: F,
) -> Result<u64>
where
    F: FnMut(u64, &[f64]) -> Result<()> + Send,
{
    let mut slot_addrs = resolve_owner_addrs(workers, &meta.layout.owners)?;
    if meta.layout.kind == LayoutKind::Replicated {
        slot_addrs.truncate(1);
    }
    let sink = Mutex::new(sink);
    let results: Vec<Result<u64>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(slot_addrs.len());
        for addr in &slot_addrs {
            let sink = &sink;
            handles.push(scope.spawn(move || fetch_one(addr, meta, start, end, opts, sink)));
        }
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| Err(Error::Server("fetch thread panicked".into())))
            })
            .collect()
    });
    let mut seen = 0u64;
    for r in results {
        seen += r?;
    }
    transfer_metrics().rows_recv.inc(seen);
    Ok(seen)
}
