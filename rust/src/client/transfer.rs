//! Standalone data-plane transfer helpers, shared by the driver-side ACI
//! (`AlchemistContext`) and sparklet *executors* — in the paper, each
//! Spark executor pushes its own partitions to the Alchemist workers
//! directly, so the routing/batching logic must be callable from any
//! thread holding only the worker address table and the matrix metadata.

use std::net::TcpStream;

use crate::elemental::Layout;
use crate::protocol::{frame, DataMsg, MatrixMeta, WireRow, WorkerInfo};
use crate::{Error, Result};

/// Route and push a set of rows to the owning Alchemist workers.
/// `workers` must contain an entry for every owner id in `meta`.
/// Returns (rows_sent, frames_sent).
pub fn push_rows(
    workers: &[WorkerInfo],
    meta: &MatrixMeta,
    rows: impl Iterator<Item = (u64, Vec<f64>)>,
    batch_rows: usize,
    nodelay: bool,
) -> Result<(u64, u64)> {
    let layout = Layout::from_desc(&meta.layout, meta.rows)?;
    let owners = &meta.layout.owners;
    let mut conns: Vec<Option<TcpStream>> = (0..owners.len()).map(|_| None).collect();
    let mut batches: Vec<Vec<WireRow>> = (0..owners.len()).map(|_| Vec::new()).collect();
    let mut rows_sent = 0u64;
    let mut frames_sent = 0u64;

    let flush = |conns: &mut Vec<Option<TcpStream>>,
                     batch: Vec<WireRow>,
                     slot: usize|
     -> Result<u64> {
        if batch.is_empty() {
            return Ok(0);
        }
        if conns[slot].is_none() {
            let info = workers
                .iter()
                .find(|w| w.id == owners[slot])
                .ok_or_else(|| Error::Server(format!("no address for worker {}", owners[slot])))?;
            let s = TcpStream::connect(&info.data_addr)?;
            if nodelay {
                s.set_nodelay(true)?;
            }
            conns[slot] = Some(s);
        }
        let msg = DataMsg::PutRows { handle: meta.handle, rows: batch };
        frame::write_frame(conns[slot].as_mut().unwrap(), &msg.encode())?;
        Ok(1)
    };

    for (index, values) in rows {
        if index >= meta.rows {
            return Err(Error::Shape(format!("row {index} out of range ({} rows)", meta.rows)));
        }
        let slot = layout.owner_slot(index) as usize;
        batches[slot].push(WireRow { index, values });
        rows_sent += 1;
        if batches[slot].len() >= batch_rows.max(1) {
            let b = std::mem::take(&mut batches[slot]);
            frames_sent += flush(&mut conns, b, slot)?;
        }
    }
    for slot in 0..owners.len() {
        let b = std::mem::take(&mut batches[slot]);
        frames_sent += flush(&mut conns, b, slot)?;
    }
    // Per-connection completion barrier: a worker processes frames on one
    // connection in order, so acking a PutDone here guarantees every row
    // this call sent has been stored before we return. Without this, a
    // subsequent `finish_put` on a *fresh* connection could overtake
    // in-flight rows (TCP orders within, not across, connections).
    for conn in conns.iter_mut().flatten() {
        frame::write_frame(conn, &DataMsg::PutDone { handle: meta.handle }.encode())?;
        match DataMsg::decode(&frame::read_frame(conn)?)? {
            DataMsg::PutComplete { .. } => {}
            DataMsg::Err { message } => return Err(Error::Server(message)),
            other => return Err(Error::Protocol(format!("unexpected {other:?}"))),
        }
    }
    Ok((rows_sent, frames_sent))
}

/// Fetch rows `[start, end)` of an Alchemist matrix, calling `sink` for
/// each row received (rows arrive per-owner, unordered across owners).
pub fn fetch_rows(
    workers: &[WorkerInfo],
    meta: &MatrixMeta,
    start: u64,
    end: u64,
    mut sink: impl FnMut(u64, Vec<f64>) -> Result<()>,
) -> Result<u64> {
    let mut seen = 0u64;
    for &id in &meta.layout.owners {
        let info = workers
            .iter()
            .find(|w| w.id == id)
            .ok_or_else(|| Error::Server(format!("no address for worker {id}")))?;
        let mut s = TcpStream::connect(&info.data_addr)?;
        s.set_nodelay(true)?;
        frame::write_frame(
            &mut s,
            &DataMsg::GetRows { handle: meta.handle, start, end }.encode(),
        )?;
        loop {
            match DataMsg::decode(&frame::read_frame(&mut s)?)? {
                DataMsg::RowBatch { rows, .. } => {
                    for row in rows {
                        sink(row.index, row.values)?;
                        seen += 1;
                    }
                }
                DataMsg::GetDone { .. } => break,
                DataMsg::Err { message } => return Err(Error::Server(message)),
                other => return Err(Error::Protocol(format!("unexpected {other:?}"))),
            }
        }
    }
    Ok(seen)
}
