//! Alchemist-Client Interface (ACI) — what a client application imports
//! (paper §3.3): `AlchemistContext` for the driver connection and
//! session lifecycle, `AlMatrix` handles for Alchemist-resident matrices,
//! and row-wise matrix transfer over data-plane sockets.
//!
//! Phase timing: every context records cumulative `send` / `compute` /
//! `receive` durations (the decomposition the paper reports in Table 1 and
//! Fig 3) in [`AlchemistContext::phases`].

pub mod transfer;
pub mod wrappers;

use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::{RetryConfig, TransferConfig};
use crate::linalg::DenseMatrix;
use crate::metrics::{PhaseTimes, Timer};
use crate::protocol::{
    frame, ClientMsg, DataMsg, DriverMsg, JobState, LayoutKind, MatrixMeta, Params,
    QosClass, RoutineDescriptor, WireCodec, WorkerInfo,
    IDEMPOTENT_SUBMIT_PROTOCOL_VERSION, MIN_PROTOCOL_VERSION, PROTOCOL_VERSION,
    QOS_PROTOCOL_VERSION, ROUTINE_ENGINE_PROTOCOL_VERSION, SLAB_PROTOCOL_VERSION,
    TELEMETRY_PROTOCOL_VERSION, TRANSPORT_PROTOCOL_VERSION,
};
use crate::telemetry::TelemetryReport;
use crate::{Error, Result};

/// Handle to a matrix resident on the Alchemist side (paper §3.3: "matrix
/// handles in the form of AlMatrix objects, which act as proxies for the
/// distributed data sets stored on Alchemist").
#[derive(Debug, Clone)]
pub struct AlMatrix {
    pub meta: MatrixMeta,
}

impl AlMatrix {
    pub fn handle(&self) -> u64 {
        self.meta.handle
    }

    pub fn rows(&self) -> u64 {
        self.meta.rows
    }

    pub fn cols(&self) -> u64 {
        self.meta.cols
    }
}

/// Server-wide pool + scheduler occupancy (reply to `ServerStatus`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerStatus {
    pub total_workers: u32,
    pub free_workers: u32,
    pub sessions: u32,
    /// Sessions parked in the admission queue right now.
    pub queued_sessions: u32,
    /// Jobs submitted but not yet `Done`/`Failed`, server-wide.
    pub jobs_inflight: u32,
    /// Workers currently quarantined, awaiting a clean health probe
    /// (v7 servers; 0 from older servers).
    pub lost_workers: u32,
    /// Workers the prober has readmitted to the pool, cumulative (v7).
    pub recovered_workers: u32,
    /// Worker re-registrations (epoch bumps) accepted, cumulative (v7).
    pub worker_epochs: u32,
    /// Parked allocation requests of class `interactive` (v11 servers;
    /// 0 from older ones).
    pub queued_interactive: u32,
    /// Parked allocation requests of class `batch` (v11).
    pub queued_batch: u32,
    /// Parked allocation requests of class `best_effort` (v11).
    pub queued_best_effort: u32,
}

/// Paper-shaped per-job phase decomposition (Table 1 / Fig. 3 of the
/// Alchemist paper: time in send / compute / receive), assembled from the
/// job's cross-process trace plus this context's transfer phase totals.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PhaseBreakdown {
    /// Client-side send seconds (`ac.phases`, cumulative for this
    /// context — transfers are not tied to a job id on the wire).
    pub send_s: f64,
    /// Server-side execution seconds: the driver's `execute` span, from
    /// worker fan-out to the job's terminal state.
    pub compute_s: f64,
    /// Client-side receive seconds (cumulative for this context).
    pub receive_s: f64,
    /// Seconds the job sat in the session's queue before its turn.
    pub queue_wait_s: f64,
    /// Driver-side parameter/handle validation seconds at submit.
    pub validate_s: f64,
    /// Wall-clock width of the job's whole trace (first span start to
    /// last span end, across driver and worker ranks). `queue_wait_s +
    /// compute_s` accounts for this window up to clock skew.
    pub total_s: f64,
}

/// Handle to an asynchronously submitted routine (`ac.run_async`): a
/// future-like object tied to its context. Poll it, or block on
/// [`wait`](JobHandle::wait) for the routine result. Dropping the handle
/// does not cancel the job — results stay in the session's job table
/// until read (and a bounded history of read results remains pollable).
pub struct JobHandle<'a> {
    ac: &'a AlchemistContext,
    pub job_id: u64,
    routine: String,
    /// Terminal state captured by `poll` so a later `wait` can return
    /// the result even if the server has since evicted the (delivered)
    /// entry from its retained history.
    terminal: Mutex<Option<JobState>>,
    /// Highest preemption count observed for this job (v11): how many
    /// times a higher-class arrival bounced it off the worker group
    /// before it completed. Updated by `poll`/`wait` whenever they see
    /// `JobState::Preempted`.
    preemptions: Mutex<u32>,
}

impl std::fmt::Debug for JobHandle<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobHandle")
            .field("job_id", &self.job_id)
            .field("routine", &self.routine)
            .finish()
    }
}

impl<'a> JobHandle<'a> {
    /// Routine name this job runs.
    pub fn routine(&self) -> &str {
        &self.routine
    }

    /// Non-blocking state snapshot. Terminal states are cached on the
    /// handle: the server counts them delivered, so the handle keeps
    /// the payload for a later [`wait`](JobHandle::wait).
    pub fn poll(&self) -> Result<JobState> {
        if let Some(state) = self.terminal.lock().unwrap().clone() {
            return Ok(state);
        }
        let state = self.ac.poll_job(self.job_id)?;
        if let JobState::Preempted { count } = &state {
            *self.preemptions.lock().unwrap() = *count;
        }
        if state.is_terminal() {
            *self.terminal.lock().unwrap() = Some(state.clone());
        }
        Ok(state)
    }

    /// How many times this job has been preempted so far (v11): a
    /// higher-class arrival bounced it off the worker group and it was
    /// re-queued. 0 until a `Preempted` state has been observed —
    /// preemption is a detour, not a failure, so a completed job with a
    /// nonzero count still returned its normal result.
    pub fn preemptions(&self) -> u32 {
        *self.preemptions.lock().unwrap()
    }

    /// True once the job is `Done` or `Failed`.
    pub fn is_finished(&self) -> Result<bool> {
        Ok(self.poll()?.is_terminal())
    }

    /// Block until the routine finishes; returns its scalar outputs and
    /// an `AlMatrix` per distributed output (exactly what the synchronous
    /// `run` returns), or the routine's error if it failed. Waiting
    /// happens in bounded server-side rounds so a slow routine never
    /// wedges the control connection against the driver's will.
    pub fn wait(self) -> Result<(Params, Vec<AlMatrix>)> {
        let t = Timer::start();
        // A terminal state already captured by `poll` short-circuits the
        // server round trip (and survives server-side history eviction).
        let mut next = self.terminal.lock().unwrap().take();
        loop {
            let state = match next.take() {
                Some(s) => s,
                None => self.ac.wait_job_round(self.job_id, 0)?,
            };
            match state {
                JobState::Done { outputs, new_matrices } => {
                    self.ac.phases.add("compute", t.elapsed());
                    return Ok((
                        outputs,
                        new_matrices.into_iter().map(|meta| AlMatrix { meta }).collect(),
                    ));
                }
                JobState::Failed { message } => {
                    // The driver already prefixes routine context; known
                    // failure classes (session poisoning) come back typed
                    // so callers can reconnect-and-retry programmatically.
                    self.ac.phases.add("compute", t.elapsed());
                    return Err(Error::from_server_message(message));
                }
                JobState::Preempted { count } => {
                    *self.preemptions.lock().unwrap() = count;
                }
                JobState::Queued | JobState::Running { .. } => {}
            }
        }
    }

    /// Cancel this job (v6): queued jobs fail instantly; running jobs
    /// get a best-effort cooperative cancel honored at the routine's
    /// next collective boundary (one Lanczos iteration / panel sweep).
    /// Returns the job's state as of the request — poll or
    /// [`wait`](JobHandle::wait) afterwards for the terminal state.
    pub fn cancel(&self) -> Result<JobState> {
        let state = self.ac.cancel_job(self.job_id)?;
        if state.is_terminal() {
            *self.terminal.lock().unwrap() = Some(state.clone());
        }
        Ok(state)
    }

    /// Per-job phase breakdown (v8): pulls the job's merged trace from
    /// the driver and reduces it to the paper's send/compute/receive
    /// row, plus the queueing/validation split only the trace can give.
    /// Works for running and finished jobs (spans live in bounded ring
    /// buffers — very old jobs may have aged out, yielding zeros).
    pub fn phase_breakdown(&self) -> Result<PhaseBreakdown> {
        let report = self.ac.fetch_telemetry(Some(self.job_id))?;
        let driver_sum = |name: &str| -> f64 {
            report
                .spans
                .iter()
                .filter(|s| s.source == "driver" && s.name == name)
                .map(|s| s.dur_us as f64 / 1e6)
                .sum()
        };
        let total_s = report
            .span_window()
            .map(|(lo, hi)| hi.saturating_sub(lo) as f64 / 1e6)
            .unwrap_or(0.0);
        Ok(PhaseBreakdown {
            send_s: self.ac.phases.get_secs("send"),
            compute_s: driver_sum("execute"),
            receive_s: self.ac.phases.get_secs("receive"),
            queue_wait_s: driver_sum("queue_wait"),
            validate_s: driver_sum("validate"),
            total_s,
        })
    }

    /// Live `(phase, completed fraction)` of a running job, pulled by
    /// the driver from the worker group; `None` when the job is not
    /// currently running (or has not reported yet — the phase is then
    /// empty).
    pub fn progress(&self) -> Result<Option<(String, f64)>> {
        match self.poll()? {
            JobState::Running { phase, progress } if !phase.is_empty() => {
                Ok(Some((phase, progress)))
            }
            _ => Ok(None),
        }
    }
}

/// The client context: one control connection to the Alchemist driver.
pub struct AlchemistContext {
    ctl: Mutex<TcpStream>,
    pub session_id: u64,
    workers: Vec<WorkerInfo>,
    /// Rows per data-plane frame (paper behaviour = 1; see ablate_framing).
    pub batch_rows: usize,
    /// Data-plane pipeline knobs (`[transfer]` config section).
    pub transfer: TransferConfig,
    /// Cumulative send/compute/receive phase times.
    pub phases: PhaseTimes,
    /// Control/data-plane retry policy (`[retry]` config section):
    /// transfer redial attempts, backoff shape, and the opt-in
    /// control-call reply deadline.
    pub retry: RetryConfig,
    /// Client-side fault plane (chaos tests/benches); `None` — the
    /// default — costs nothing on any path.
    fault: Option<Arc<crate::fault::FaultPlane>>,
    /// QoS class this session requests workers (and, by inheritance,
    /// runs unclassed submissions) under — v11 sessions only; older
    /// sessions never put it on the wire. Defaults to `None`, which
    /// leaves the field off the wire so the server resolves its own
    /// `sched.default_class`; set `Some(..)` to pin a class explicitly.
    pub qos_class: Option<QosClass>,
    /// Monotonic source of v10 submission nonces (starts at 1; nonce 0
    /// on the wire means "no dedup").
    nonce_counter: AtomicU64,
    nodelay: bool,
    /// Protocol version negotiated at handshake (`min(client, server)`).
    negotiated: u16,
    /// Wire-codec capability mask the server advertised in the v9
    /// `TransferCaps` exchange (0 for ≤ v8 sessions — which also keeps
    /// [`wire_codec`](Self::wire_codec) at `None` by construction).
    server_caps: u32,
}

impl AlchemistContext {
    /// Connect + handshake (§3.2 step 2).
    pub fn connect(driver_addr: &str, app_name: &str) -> Result<AlchemistContext> {
        let mut conn = TcpStream::connect(driver_addr)?;
        conn.set_nodelay(true)?;
        frame::write_frame(
            &mut conn,
            &ClientMsg::Handshake { app_name: app_name.into(), version: PROTOCOL_VERSION }
                .encode(),
        )?;
        let reply = DriverMsg::decode(&frame::read_frame(&mut conn)?)?.into_result()?;
        let DriverMsg::HandshakeAck { session_id, version } = reply else {
            return Err(Error::Protocol(format!("unexpected handshake reply {reply:?}")));
        };
        if !(MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&version) {
            return Err(Error::Protocol(format!(
                "server negotiated unsupported protocol v{version} \
                 (we speak v{MIN_PROTOCOL_VERSION}..=v{PROTOCOL_VERSION})"
            )));
        }
        // v9 capability exchange: advertise every codec we can encode and
        // remember which the server can decode. ≤ v8 servers never see
        // this frame and the mask stays 0 (= plain TCP/uncompressed).
        let mut server_caps = 0u32;
        if version >= TRANSPORT_PROTOCOL_VERSION {
            frame::write_frame(
                &mut conn,
                &ClientMsg::TransferCaps { codecs: WireCodec::mask_all() }.encode(),
            )?;
            match DriverMsg::decode(&frame::read_frame(&mut conn)?)?.into_result()? {
                DriverMsg::TransferCaps { codecs } => server_caps = codecs,
                other => {
                    return Err(Error::Protocol(format!(
                        "unexpected TransferCaps reply {other:?}"
                    )))
                }
            }
        }
        Ok(AlchemistContext {
            ctl: Mutex::new(conn),
            session_id,
            workers: vec![],
            batch_rows: 256,
            transfer: TransferConfig::default(),
            phases: PhaseTimes::new(),
            retry: RetryConfig::default(),
            fault: None,
            qos_class: None,
            nonce_counter: AtomicU64::new(1),
            nodelay: true,
            negotiated: version,
            server_caps,
        })
    }

    /// Protocol version negotiated with the server at handshake.
    pub fn protocol_version(&self) -> u16 {
        self.negotiated
    }

    /// True once the session speaks the v5 slab data plane.
    pub fn slab_negotiated(&self) -> bool {
        self.negotiated >= SLAB_PROTOCOL_VERSION
    }

    /// Codec capability mask the server advertised (0 on ≤ v8 sessions).
    pub fn transfer_caps(&self) -> u32 {
        self.server_caps
    }

    /// The wire codec this session's transfers actually use: the
    /// configured `[transfer] compression`, gated on the session speaking
    /// v9 *and* the server having advertised that codec in the
    /// `TransferCaps` exchange. The lossy `f32` downcast is never
    /// auto-negotiated — it reaches here only via explicit config, and
    /// even then only when the server claims it.
    pub fn wire_codec(&self) -> WireCodec {
        if self.negotiated < TRANSPORT_PROTOCOL_VERSION {
            return WireCodec::None;
        }
        let codec = WireCodec::parse(&self.transfer.compression).unwrap_or(WireCodec::None);
        if self.server_caps & codec.bit() != 0 {
            codec
        } else {
            WireCodec::None
        }
    }

    /// Transfer options for this context: config knobs + the negotiated
    /// wire format (slab frames only once the session speaks v5; a codec
    /// only once `TransferCaps` confirmed it).
    fn transfer_opts(&self) -> transfer::TransferOptions {
        let mut opts = transfer::TransferOptions::new(
            &self.transfer,
            self.batch_rows,
            self.nodelay,
            self.negotiated >= SLAB_PROTOCOL_VERSION,
        );
        opts.codec = self.wire_codec();
        opts.retry = self.retry.clone();
        opts.fault = self.fault.clone();
        opts
    }

    /// Install a client-side fault plane: transfer dials and streams are
    /// wrapped by `fault::wrap_connector`, letting chaos tests perturb
    /// the data plane deterministically. `None` (the default) leaves
    /// every path untouched.
    pub fn set_fault_plane(&mut self, plane: Option<Arc<crate::fault::FaultPlane>>) {
        self.fault = plane;
    }

    /// One control-plane request/reply exchange. Frames encode at the
    /// negotiated session version, so ≤ v9 servers keep receiving their
    /// legacy byte shapes. Socket-level failures come back typed as
    /// [`Error::DriverGone`]: the driver tears down its session side on
    /// disconnect, so the whole connection — not just this call — is over.
    ///
    /// With `[retry] call_timeout_ms` set, every call gets a reply
    /// deadline (so a dropped reply can never hang the client), and
    /// *idempotent* requests — nonce-carrying `SubmitRoutine` (the v10
    /// driver answers a replay with the original job id), `PollJob`,
    /// `WaitJob`, `ServerStatus`, `FetchTelemetry` — are re-sent with
    /// backoff up to `retry.max_attempts` before giving up. The deadline
    /// must exceed the server's `sched.waitjob_block_ms` or blocking
    /// waits will resend spuriously (harmless, but wasteful).
    fn call(&self, msg: &ClientMsg) -> Result<DriverMsg> {
        let mut s = self.ctl.lock().unwrap();
        let bytes = msg.encode_versioned(self.negotiated);
        let deadline_ms = self.retry.call_timeout_ms;
        if deadline_ms == 0 {
            frame::write_frame(&mut *s, &bytes).map_err(Error::into_driver_gone)?;
            let buf = frame::read_frame(&mut *s).map_err(Error::into_driver_gone)?;
            return DriverMsg::decode(&buf)?.into_result();
        }
        let attempts = if idempotent_request(msg) { self.retry.max_attempts.max(1) } else { 1 };
        let deadline = Duration::from_millis(deadline_ms);
        let mut attempt = 0u32;
        loop {
            frame::write_frame(&mut *s, &bytes).map_err(Error::into_driver_gone)?;
            let _ = s.set_read_timeout(Some(deadline));
            let res = frame::read_frame(&mut *s);
            let _ = s.set_read_timeout(None);
            match res {
                Ok(buf) => {
                    if attempt > 0 {
                        // A resend can race a merely-slow original reply;
                        // both replies are identical (the request was
                        // idempotent), so drain the straggler before it
                        // can desync a later call. Best-effort: bounded
                        // by a short read timeout.
                        let _ = s.set_read_timeout(Some(Duration::from_millis(20)));
                        while frame::read_frame(&mut *s).is_ok() {}
                        let _ = s.set_read_timeout(None);
                    }
                    return DriverMsg::decode(&buf)?.into_result();
                }
                Err(Error::Io(e))
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    attempt += 1;
                    if attempt >= attempts {
                        return Err(Error::DriverGone(format!(
                            "no reply within {deadline_ms}ms after {attempt} attempt(s)"
                        )));
                    }
                    std::thread::sleep(crate::fault::retry_backoff(
                        attempt,
                        self.retry.backoff_base_ms,
                        self.retry.backoff_cap_ms,
                        self.session_id,
                    ));
                }
                Err(e) => return Err(e.into_driver_gone()),
            }
        }
    }

    /// Request a worker group (§3.2 step 3). Fails immediately when the
    /// pool is short (the paper's behaviour); see
    /// [`request_workers_wait`](Self::request_workers_wait) for queued
    /// admission.
    pub fn request_workers(&mut self, count: u32) -> Result<&[WorkerInfo]> {
        self.request_workers_inner(count, false, 0)
    }

    /// Request a worker group, parking in the driver's FIFO admission
    /// queue if the pool is currently short. `timeout_ms = 0` uses the
    /// server's `sched.wait_timeout_ms` default.
    pub fn request_workers_wait(
        &mut self,
        count: u32,
        timeout_ms: u64,
    ) -> Result<&[WorkerInfo]> {
        self.request_workers_inner(count, true, timeout_ms)
    }

    fn request_workers_inner(
        &mut self,
        count: u32,
        wait: bool,
        timeout_ms: u64,
    ) -> Result<&[WorkerInfo]> {
        // An explicitly-set class rides the request; the `None` default
        // stays off the wire so the server applies `sched.default_class`
        // (and `encode_versioned` drops the field below v11 either way).
        let msg = ClientMsg::RequestWorkers {
            count,
            wait,
            timeout_ms,
            class: self.qos_class,
            deadline_ms: 0,
        };
        match self.call(&msg)? {
            DriverMsg::WorkersGranted { workers } => {
                self.workers = workers;
                Ok(&self.workers)
            }
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    pub fn workers(&self) -> &[WorkerInfo] {
        &self.workers
    }

    /// Register an MPI-library wrapper by name/path (§3.3).
    pub fn register_library(&self, name: &str, path: &str) -> Result<()> {
        match self.call(&ClientMsg::RegisterLibrary { name: name.into(), path: path.into() })? {
            DriverMsg::LibraryRegistered { .. } => Ok(()),
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// Allocate an empty distributed matrix for a subsequent row transfer.
    pub fn create_matrix(&self, rows: u64, cols: u64, kind: LayoutKind) -> Result<AlMatrix> {
        match self.call(&ClientMsg::CreateMatrix { rows, cols, kind })? {
            DriverMsg::MatrixCreated { meta } => Ok(AlMatrix { meta }),
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    fn worker_info(&self, id: u32) -> Result<&WorkerInfo> {
        self.workers
            .iter()
            .find(|w| w.id == id)
            .ok_or_else(|| Error::Server(format!("worker {id} not in session grant")))
    }

    /// Send rows to the owning workers (callable concurrently from many
    /// threads with disjoint row sets — our stand-in for parallel Spark
    /// executors each pushing their partitions). Rows are routed by the
    /// matrix layout, packed into slab batches, and pipelined through
    /// per-owner sender threads (see `transfer::push_rows`). Rows may be
    /// owned (`Vec<f64>`) or borrowed (`&[f64]`) — they are copied into
    /// the outgoing slab either way.
    pub fn put_rows<V: AsRef<[f64]>>(
        &self,
        m: &AlMatrix,
        rows: impl Iterator<Item = (u64, V)>,
    ) -> Result<()> {
        let t = Timer::start();
        transfer::push_rows(&self.workers, &m.meta, rows, &self.transfer_opts())?;
        self.phases.add("send", t.elapsed());
        Ok(())
    }

    /// Finish a transfer: ask every owner to confirm receipt; errors if
    /// the counts don't add up to the full matrix. Dials through the
    /// configured transport, so co-located workers are confirmed over
    /// the same UDS fast path the rows took.
    pub fn finish_put(&self, m: &AlMatrix) -> Result<u64> {
        let t = Timer::start();
        let opts = self.transfer_opts();
        let mut total = 0u64;
        for &id in &m.meta.layout.owners {
            let info = self.worker_info(id)?;
            // PutDone is idempotent on the worker (it reports, never
            // mutates), so a dropped data connection here retries on the
            // same ladder the slab lanes use.
            let attempts = self.retry.max_attempts.max(1);
            let mut attempt = 0u32;
            total += loop {
                let confirm = (|| -> Result<u64> {
                    let mut s = transfer::dial_worker(info, &opts)?;
                    frame::write_frame(
                        &mut s,
                        &DataMsg::PutDone { handle: m.meta.handle }.encode(),
                    )?;
                    match DataMsg::decode(&frame::read_frame(&mut s)?)? {
                        DataMsg::PutComplete { rows_received, .. } => Ok(rows_received),
                        DataMsg::Err { message } => Err(Error::Server(message)),
                        other => Err(Error::Protocol(format!("unexpected {other:?}"))),
                    }
                })();
                match confirm {
                    Ok(rows) => break rows,
                    Err(e) if e.is_transient_io() && attempt + 1 < attempts => {
                        attempt += 1;
                        crate::metrics::transfer_metrics().retry_attempts.inc(1);
                        std::thread::sleep(crate::fault::retry_backoff(
                            attempt,
                            self.retry.backoff_base_ms,
                            self.retry.backoff_cap_ms,
                            m.meta.handle ^ u64::from(id),
                        ));
                    }
                    Err(e) => {
                        if e.is_transient_io() {
                            crate::metrics::transfer_metrics().retry_exhausted.inc(1);
                        }
                        return Err(e);
                    }
                }
            };
        }
        self.phases.add("send", t.elapsed());
        if total != m.meta.rows {
            return Err(Error::Server(format!(
                "transfer incomplete: {total}/{} rows received",
                m.meta.rows
            )));
        }
        Ok(total)
    }

    /// Convenience: send a local dense matrix (rows borrowed straight out
    /// of the matrix storage — no per-row staging copies).
    pub fn send_dense(&self, a: &DenseMatrix, kind: LayoutKind) -> Result<AlMatrix> {
        let m = self.create_matrix(a.rows() as u64, a.cols() as u64, kind)?;
        self.put_rows(&m, (0..a.rows()).map(|i| (i as u64, a.row(i))))?;
        self.finish_put(&m)?;
        Ok(m)
    }

    /// Invoke `library.routine(params)` (§3.3 `ac.run`). Returns scalar
    /// outputs and an `AlMatrix` per distributed output.
    ///
    /// Since protocol v4 this is sugar over the async job path: submit,
    /// then block on the handle. Semantics are unchanged; the driver
    /// executes the routine the same way either path is taken.
    pub fn run(
        &self,
        library: &str,
        routine: &str,
        params: Params,
    ) -> Result<(Params, Vec<AlMatrix>)> {
        self.run_async(library, routine, params)?.wait()
    }

    /// Submit `library.routine(params)` as an asynchronous job and return
    /// immediately with a [`JobHandle`]. The driver queues the routine
    /// (jobs within one session execute in submission order on the SPMD
    /// worker group) and the control connection stays free, so several
    /// jobs can be in flight at once — the oversubscription/pipelining
    /// mode the `sched` subsystem exists for.
    pub fn run_async(
        &self,
        library: &str,
        routine: &str,
        params: Params,
    ) -> Result<JobHandle<'_>> {
        self.submit_inner(library, routine, params, None, 0)
    }

    /// [`run_async`](Self::run_async) with an explicit QoS class and
    /// deadline hint (v11): the class overrides the session's for this
    /// one job, and a nonzero `deadline_ms` asks the driver to count the
    /// job against its `deadline_missed` telemetry when queue wait
    /// exceeds it (advisory — the job still runs).
    pub fn run_async_with_class(
        &self,
        library: &str,
        routine: &str,
        params: Params,
        class: QosClass,
        deadline_ms: u64,
    ) -> Result<JobHandle<'_>> {
        self.need_v11("classed submission")?;
        self.submit_inner(library, routine, params, Some(class), deadline_ms)
    }

    fn submit_inner(
        &self,
        library: &str,
        routine: &str,
        params: Params,
        class: Option<QosClass>,
        deadline_ms: u64,
    ) -> Result<JobHandle<'_>> {
        // v10: mint a per-submission idempotency nonce so a re-sent
        // Submit (reply deadline hit, driver dropped the reply) maps to
        // the same job instead of running the routine twice. ≤ v9
        // sessions get nonce 0 — and never see the field on the wire.
        let nonce = if self.negotiated >= IDEMPOTENT_SUBMIT_PROTOCOL_VERSION {
            self.nonce_counter.fetch_add(1, Ordering::Relaxed)
        } else {
            0
        };
        let reply = self.call(&ClientMsg::SubmitRoutine {
            library: library.into(),
            routine: routine.into(),
            params,
            nonce,
            class,
            deadline_ms,
        })?;
        match reply {
            DriverMsg::JobAccepted { job_id } => Ok(JobHandle {
                ac: self,
                job_id,
                routine: routine.to_string(),
                terminal: Mutex::new(None),
                preemptions: Mutex::new(0),
            }),
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// Non-blocking job-state snapshot.
    pub fn poll_job(&self, job_id: u64) -> Result<JobState> {
        match self.call(&ClientMsg::PollJob { job_id })? {
            DriverMsg::JobStatus { state, .. } => Ok(state),
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// One bounded server-side wait round (the server caps each round at
    /// `sched.waitjob_block_ms`); returns the state when the round ends.
    pub fn wait_job_round(&self, job_id: u64, timeout_ms: u64) -> Result<JobState> {
        match self.call(&ClientMsg::WaitJob { job_id, timeout_ms })? {
            DriverMsg::JobStatus { state, .. } => Ok(state),
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    fn need_v6(&self, what: &str) -> Result<()> {
        if self.negotiated < ROUTINE_ENGINE_PROTOCOL_VERSION {
            return Err(Error::Protocol(format!(
                "{what} needs protocol v{ROUTINE_ENGINE_PROTOCOL_VERSION}+, session \
                 negotiated v{}",
                self.negotiated
            )));
        }
        Ok(())
    }

    fn need_v8(&self, what: &str) -> Result<()> {
        if self.negotiated < TELEMETRY_PROTOCOL_VERSION {
            return Err(Error::Protocol(format!(
                "{what} needs protocol v{TELEMETRY_PROTOCOL_VERSION}+, session \
                 negotiated v{}",
                self.negotiated
            )));
        }
        Ok(())
    }

    fn need_v11(&self, what: &str) -> Result<()> {
        if self.negotiated < QOS_PROTOCOL_VERSION {
            return Err(Error::Protocol(format!(
                "{what} needs protocol v{QOS_PROTOCOL_VERSION}+, session \
                 negotiated v{}",
                self.negotiated
            )));
        }
        Ok(())
    }

    /// Pull the server's merged telemetry report (v8): the driver's
    /// registry snapshot (`sched.` / `transfer.` / `compute.` prefixes)
    /// summed with every session worker's (`w{id}.` prefixes), plus the
    /// stitched cross-process span timeline. `Some(job_id)` filters the
    /// spans to that job's trace; `None` returns the full snapshot,
    /// ambient spans included.
    pub fn fetch_telemetry(&self, job_id: Option<u64>) -> Result<TelemetryReport> {
        self.need_v8("FetchTelemetry")?;
        match self.call(&ClientMsg::FetchTelemetry { job_id: job_id.unwrap_or(0) })? {
            DriverMsg::Telemetry(report) => Ok(report),
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// Cancel a job by id (v6); see [`JobHandle::cancel`].
    pub fn cancel_job(&self, job_id: u64) -> Result<JobState> {
        self.need_v6("CancelJob")?;
        match self.call(&ClientMsg::CancelJob { job_id })? {
            DriverMsg::JobStatus { state, .. } => Ok(state),
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// Introspect a registered library's routines (v6): names, typed
    /// parameter schemas (with defaults and requiredness) and output
    /// roles, straight from the server-side routine specs.
    pub fn describe_routines(&self, library: &str) -> Result<Vec<RoutineDescriptor>> {
        self.need_v6("DescribeRoutines")?;
        match self.call(&ClientMsg::DescribeRoutines { library: library.into() })? {
            DriverMsg::RoutineList { routines } => Ok(routines),
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// Materialize an Alchemist matrix back into client memory — the
    /// explicit AlMatrix -> local conversion of §3.3 ("Only when the user
    /// explicitly converts this object ... will the data be sent").
    /// Fetches from all owner workers in parallel (one thread per worker
    /// stream — §Perf: the serial fetch was the receive-phase bottleneck),
    /// copying each row straight from the receive slab into the output.
    pub fn fetch_dense(&self, m: &AlMatrix) -> Result<DenseMatrix> {
        let t = Timer::start();
        let cols = m.meta.cols as usize;
        let rows = m.meta.rows;
        let mut out = DenseMatrix::zeros(rows as usize, cols);
        let seen = {
            let out = &mut out;
            transfer::fetch_rows(
                &self.workers,
                &m.meta,
                0,
                rows,
                &self.transfer_opts(),
                move |index, values| {
                    if index >= rows {
                        return Err(Error::Server(format!("fetched row {index} out of range")));
                    }
                    if values.len() != cols {
                        return Err(Error::Shape("fetched row width mismatch".into()));
                    }
                    out.row_mut(index as usize).copy_from_slice(values);
                    Ok(())
                },
            )?
        };
        self.phases.add("receive", t.elapsed());
        if seen != m.meta.rows {
            return Err(Error::Server(format!("fetched {seen}/{} rows", m.meta.rows)));
        }
        Ok(out)
    }

    /// Release an Alchemist-side matrix.
    pub fn release(&self, m: AlMatrix) -> Result<()> {
        match self.call(&ClientMsg::ReleaseMatrix { handle: m.meta.handle })? {
            DriverMsg::Released { .. } => Ok(()),
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// Server-wide pool status: (total workers, free workers, sessions).
    pub fn server_status(&self) -> Result<(u32, u32, u32)> {
        let s = self.scheduler_status()?;
        Ok((s.total_workers, s.free_workers, s.sessions))
    }

    /// Full server status including scheduler occupancy and (v7) the
    /// worker-pool recovery counters.
    pub fn scheduler_status(&self) -> Result<ServerStatus> {
        match self.call(&ClientMsg::ServerStatus)? {
            DriverMsg::Status {
                total_workers,
                free_workers,
                sessions,
                queued_sessions,
                jobs_inflight,
                lost_workers,
                recovered_workers,
                worker_epochs,
                queued_by_class,
            } => Ok(ServerStatus {
                total_workers,
                free_workers,
                sessions,
                queued_sessions,
                jobs_inflight,
                lost_workers,
                recovered_workers,
                worker_epochs,
                queued_interactive: queued_by_class[QosClass::Interactive.idx()],
                queued_batch: queued_by_class[QosClass::Batch.idx()],
                queued_best_effort: queued_by_class[QosClass::BestEffort.idx()],
            }),
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// Close the session (§3.3 `ac.stop()`).
    pub fn stop(self) -> Result<()> {
        match self.call(&ClientMsg::Stop)? {
            DriverMsg::Stopped => Ok(()),
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }
}

/// True for requests the client may safely re-send after a reply
/// deadline: pure reads, plus `SubmitRoutine` once it carries a real
/// idempotency nonce (the v10 driver dedups the replay by nonce).
fn idempotent_request(msg: &ClientMsg) -> bool {
    match msg {
        ClientMsg::SubmitRoutine { nonce, .. } => *nonce != 0,
        ClientMsg::PollJob { .. }
        | ClientMsg::WaitJob { .. }
        | ClientMsg::ServerStatus
        | ClientMsg::FetchTelemetry { .. } => true,
        _ => false,
    }
}
