//! Library wrappers (paper §3.4): thin, MLlib-shaped sugar over `ac.run`
//! so application code reads like `CondEst(alA)` instead of raw
//! (library, routine, params) triples.

use crate::ali::params::ParamsBuilder;
use crate::client::{AlMatrix, AlchemistContext, JobHandle};
use crate::{Error, Result};

/// Register the builtin ElemLib under its conventional name.
pub fn register_elemlib(ac: &AlchemistContext) -> Result<()> {
    ac.register_library("elemlib", "builtin:elemlib")
}

/// `C = A · B` — the paper's §4.1 operation.
pub fn gemm(ac: &AlchemistContext, a: &AlMatrix, b: &AlMatrix) -> Result<AlMatrix> {
    let params = ParamsBuilder::new().matrix("A", a.handle()).matrix("B", b.handle()).build();
    let (_, mut mats) = ac.run("elemlib", "gemm", params)?;
    mats.pop().ok_or_else(|| Error::Ali("gemm returned no matrix".into()))
}

/// `C = A · B` with an explicit distributed algorithm ("ring" |
/// "allgather" | "summa2d") and optional sub-panel rows (0 = whole owned panels),
/// overriding the server's `[compute]` defaults — the
/// `table1_matmul`/`ablate_gemm_backend` ablation hook.
pub fn gemm_with_algo(
    ac: &AlchemistContext,
    a: &AlMatrix,
    b: &AlMatrix,
    algo: &str,
    panel_rows: u32,
) -> Result<AlMatrix> {
    let params = ParamsBuilder::new()
        .matrix("A", a.handle())
        .matrix("B", b.handle())
        .str("algo", algo)
        .i64("panel_rows", panel_rows as i64)
        .build();
    let (_, mut mats) = ac.run("elemlib", "gemm", params)?;
    mats.pop().ok_or_else(|| Error::Ali("gemm returned no matrix".into()))
}

/// `C = A · B` on an explicit summa2d process grid ("auto" or "RxC";
/// a fixed shape must tile the worker group). `panel_rows` is the
/// k-panel width (0 = ceil(k/p)).
pub fn gemm_with_grid(
    ac: &AlchemistContext,
    a: &AlMatrix,
    b: &AlMatrix,
    grid: &str,
    panel_rows: u32,
) -> Result<AlMatrix> {
    let params = ParamsBuilder::new()
        .matrix("A", a.handle())
        .matrix("B", b.handle())
        .str("algo", "summa2d")
        .str("grid", grid)
        .i64("panel_rows", panel_rows as i64)
        .build();
    let (_, mut mats) = ac.run("elemlib", "gemm", params)?;
    mats.pop().ok_or_else(|| Error::Ali("gemm returned no matrix".into()))
}

/// Asynchronous `C = A · B`: returns a [`JobHandle`] immediately so the
/// caller can pipeline further submissions (`sched` job queue).
pub fn gemm_async<'a>(
    ac: &'a AlchemistContext,
    a: &AlMatrix,
    b: &AlMatrix,
) -> Result<JobHandle<'a>> {
    let params = ParamsBuilder::new().matrix("A", a.handle()).matrix("B", b.handle()).build();
    ac.run_async("elemlib", "gemm", params)
}

/// Asynchronous Frobenius norm; `handle.wait()` yields the scalar in its
/// outputs under `"fro_norm"`.
pub fn fro_norm_async<'a>(ac: &'a AlchemistContext, a: &AlMatrix) -> Result<JobHandle<'a>> {
    let params = ParamsBuilder::new().matrix("A", a.handle()).build();
    ac.run_async("elemlib", "fro_norm", params)
}

/// Truncated SVD result handles (all still resident on Alchemist).
pub struct TsvdHandles {
    pub u: AlMatrix,
    pub s: AlMatrix,
    pub v: AlMatrix,
    /// Gram-operator applications performed by the Lanczos solver.
    pub matvecs: i64,
}

/// Rank-k truncated SVD — the paper's §4.2 operation (MLlib
/// `computeSVD`-shaped).
pub fn truncated_svd(ac: &AlchemistContext, a: &AlMatrix, k: usize) -> Result<TsvdHandles> {
    let params = ParamsBuilder::new().matrix("A", a.handle()).i64("k", k as i64).build();
    let (outputs, mats) = ac.run("elemlib", "truncated_svd", params)?;
    if mats.len() != 3 {
        return Err(Error::Ali(format!("truncated_svd returned {} matrices", mats.len())));
    }
    let mut it = mats.into_iter();
    let matvecs = outputs
        .iter()
        .find(|(k, _)| k == "matvecs")
        .and_then(|(_, v)| v.as_i64().ok())
        .unwrap_or(0);
    Ok(TsvdHandles {
        u: it.next().unwrap(),
        s: it.next().unwrap(),
        v: it.next().unwrap(),
        matvecs,
    })
}

/// Condition-number estimate — the paper's §3.4 `CondEst` example.
pub fn cond_est(ac: &AlchemistContext, a: &AlMatrix) -> Result<f64> {
    let params = ParamsBuilder::new().matrix("A", a.handle()).build();
    let (outputs, _) = ac.run("elemlib", "condest", params)?;
    outputs
        .iter()
        .find(|(k, _)| k == "condest")
        .map(|(_, v)| v.as_f64())
        .transpose()?
        .ok_or_else(|| Error::Ali("condest returned no value".into()))
}

/// B = Aᵀ, distributed.
pub fn transpose(ac: &AlchemistContext, a: &AlMatrix) -> Result<AlMatrix> {
    let params = ParamsBuilder::new().matrix("A", a.handle()).build();
    let (_, mut mats) = ac.run("elemlib", "transpose", params)?;
    mats.pop().ok_or_else(|| Error::Ali("transpose returned no matrix".into()))
}

/// G = AᵀA (MLlib `computeGramianMatrix` analogue).
pub fn gramian(ac: &AlchemistContext, a: &AlMatrix) -> Result<AlMatrix> {
    let params = ParamsBuilder::new().matrix("A", a.handle()).build();
    let (_, mut mats) = ac.run("elemlib", "gramian", params)?;
    mats.pop().ok_or_else(|| Error::Ali("gramian returned no matrix".into()))
}

/// Column means/stddevs as an n x 2 matrix.
pub fn col_stats(ac: &AlchemistContext, a: &AlMatrix) -> Result<AlMatrix> {
    let params = ParamsBuilder::new().matrix("A", a.handle()).build();
    let (_, mut mats) = ac.run("elemlib", "col_stats", params)?;
    mats.pop().ok_or_else(|| Error::Ali("col_stats returned no matrix".into()))
}

/// Least squares min ‖Ax − y‖ via distributed normal equations;
/// returns (x handle, residual norm).
pub fn lstsq(
    ac: &AlchemistContext,
    a: &AlMatrix,
    y: &AlMatrix,
    ridge: f64,
) -> Result<(AlMatrix, f64)> {
    let params = ParamsBuilder::new()
        .matrix("A", a.handle())
        .matrix("y", y.handle())
        .f64("ridge", ridge)
        .build();
    let (outputs, mut mats) = ac.run("elemlib", "lstsq", params)?;
    let x = mats.pop().ok_or_else(|| Error::Ali("lstsq returned no matrix".into()))?;
    let residual = outputs
        .iter()
        .find(|(k, _)| k == "residual")
        .and_then(|(_, v)| v.as_f64().ok())
        .unwrap_or(f64::NAN);
    Ok((x, residual))
}

/// Frobenius norm of an Alchemist-resident matrix.
pub fn fro_norm(ac: &AlchemistContext, a: &AlMatrix) -> Result<f64> {
    let params = ParamsBuilder::new().matrix("A", a.handle()).build();
    let (outputs, _) = ac.run("elemlib", "fro_norm", params)?;
    outputs
        .iter()
        .find(|(k, _)| k == "fro_norm")
        .map(|(_, v)| v.as_f64())
        .transpose()?
        .ok_or_else(|| Error::Ali("fro_norm returned no value".into()))
}
