//! PJRT runtime — loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them on the request path.
//!
//! Threading: the `xla` crate's wrapper types hold raw C++ pointers and are
//! deliberately not `Send`, so all PJRT state lives on one dedicated
//! *runtime thread* that owns the `PjRtClient` and the compiled-executable
//! cache (one executable per artifact — "one compiled executable per model
//! variant"). Workers submit jobs as plain `Vec<f64>` buffers over an mpsc
//! channel and block on a reply channel; the PJRT CPU client parallelizes
//! each execution internally. Python is *never* on this path — artifacts
//! are produced once by `make artifacts`.

pub mod tiling;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};

use crate::{Error, Result};

/// One artifact input: either a volatile buffer (copied to the device per
/// call) or a cache-keyed panel that is uploaded once and stays
/// device-resident across calls (the Lanczos hot-path optimization — the
/// A panel never changes between iterations, so re-copying it every
/// matvec is pure waste; see EXPERIMENTS.md §Perf).
pub enum JobInput {
    Volatile(Vec<f64>, Vec<i64>),
    Cached { key: u64, data: Arc<Vec<f64>>, dims: Vec<i64> },
}

enum Msg {
    Job(Job),
    /// Drop all cached buffers whose key has this base (see [`cache_key`]).
    InvalidateBase(u64),
}

/// One execution request. Output: the artifact's single (tupled) result.
struct Job {
    artifact: String,
    inputs: Vec<JobInput>,
    reply: mpsc::Sender<Result<Vec<f64>>>,
}

/// Cache keys are `(base << 20) | chunk`: `base` identifies the logical
/// matrix (e.g. its Alchemist handle), `chunk` the tile within it.
pub fn cache_key(base: u64, chunk: u64) -> u64 {
    (base << 20) | (chunk & 0xF_FFFF)
}

/// Handle to the runtime thread. Cheap to clone; all clones feed the same
/// executor cache.
#[derive(Clone)]
pub struct PjrtRuntime {
    /// One PJRT client per thread — the "each MPI rank owns its BLAS"
    /// model. Jobs with cached inputs route by cache base (buffer
    /// affinity); volatile-only jobs round-robin.
    txs: Vec<mpsc::Sender<Msg>>,
    rr: Arc<std::sync::atomic::AtomicUsize>,
    dir: PathBuf,
}

impl std::fmt::Debug for PjrtRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtRuntime")
            .field("dir", &self.dir)
            .field("threads", &self.txs.len())
            .finish()
    }
}

impl PjrtRuntime {
    /// Start a runtime pool serving artifacts from `dir` (auto-sized).
    pub fn start(dir: impl AsRef<Path>) -> Result<PjrtRuntime> {
        let threads = std::thread::available_parallelism()
            .map(|v| v.get())
            .unwrap_or(4)
            .clamp(2, 8);
        Self::start_pool(dir, threads)
    }

    /// Start a runtime pool with an explicit thread count.
    pub fn start_pool(dir: impl AsRef<Path>, threads: usize) -> Result<PjrtRuntime> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.exists() {
            return Err(Error::Runtime(format!(
                "artifacts directory {} missing — run `make artifacts`",
                dir.display()
            )));
        }
        let mut txs = Vec::with_capacity(threads.max(1));
        for i in 0..threads.max(1) {
            let (tx, rx) = mpsc::channel::<Msg>();
            let thread_dir = dir.clone();
            std::thread::Builder::new()
                .name(format!("pjrt-runtime-{i}"))
                .spawn(move || runtime_thread(thread_dir, rx))
                .map_err(|e| Error::Runtime(format!("spawn runtime thread: {e}")))?;
            txs.push(tx);
        }
        Ok(PjrtRuntime {
            txs,
            rr: Arc::new(std::sync::atomic::AtomicUsize::new(0)),
            dir,
        })
    }

    /// Process-wide shared runtime (examples/benches/workers share one
    /// accelerator, like node-local BLAS shares cores).
    pub fn global(dir: impl AsRef<Path>) -> Result<&'static PjrtRuntime> {
        static GLOBAL: OnceLock<PjrtRuntime> = OnceLock::new();
        if let Some(rt) = GLOBAL.get() {
            return Ok(rt);
        }
        let rt = PjrtRuntime::start(dir)?;
        Ok(GLOBAL.get_or_init(|| rt))
    }

    /// Locate the artifacts directory: explicit config value, else walk up
    /// from CWD looking for `artifacts/` (so tests/benches work from any
    /// workspace subdir).
    pub fn find_artifacts_dir(configured: &str) -> Result<PathBuf> {
        let p = PathBuf::from(configured);
        if p.exists() {
            return Ok(p);
        }
        let mut cur = std::env::current_dir()?;
        loop {
            let cand = cur.join("artifacts");
            if cand.exists() {
                return Ok(cand);
            }
            if !cur.pop() {
                return Err(Error::Runtime(format!(
                    "cannot locate artifacts dir (configured: {configured}) — run `make artifacts`"
                )));
            }
        }
    }

    /// Execute `artifact` with volatile inputs; blocks until done.
    pub fn execute(&self, artifact: &str, inputs: Vec<(Vec<f64>, Vec<i64>)>) -> Result<Vec<f64>> {
        self.execute_with(
            artifact,
            inputs.into_iter().map(|(d, dims)| JobInput::Volatile(d, dims)).collect(),
        )
    }

    /// Execute with a mix of cached (device-resident) and volatile inputs.
    pub fn execute_with(&self, artifact: &str, inputs: Vec<JobInput>) -> Result<Vec<f64>> {
        // Cached inputs pin the job to the thread holding their buffers.
        let thread = inputs
            .iter()
            .find_map(|i| match i {
                JobInput::Cached { key, .. } => Some((key >> 20) as usize % self.txs.len()),
                _ => None,
            })
            .unwrap_or_else(|| {
                self.rr.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % self.txs.len()
            });
        let (reply_tx, reply_rx) = mpsc::channel();
        self.txs[thread]
            .send(Msg::Job(Job { artifact: artifact.to_string(), inputs, reply: reply_tx }))
            .map_err(|_| Error::Runtime("runtime thread gone".into()))?;
        reply_rx.recv().map_err(|_| Error::Runtime("runtime thread dropped reply".into()))?
    }

    /// Drop every cached buffer belonging to `base` (fire-and-forget).
    pub fn invalidate_base(&self, base: u64) {
        for tx in &self.txs {
            let _ = tx.send(Msg::InvalidateBase(base));
        }
    }

    /// True if the artifact file exists (without compiling it).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }
}

/// All PJRT state, owned by the runtime thread.
struct RtState {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Device-resident input buffers keyed by [`cache_key`].
    buffers: HashMap<u64, xla::PjRtBuffer>,
}

fn runtime_thread(dir: PathBuf, rx: mpsc::Receiver<Msg>) {
    // The client is created lazily so a missing libxla only fails jobs,
    // not process startup.
    let mut state: Option<RtState> = None;
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Job(job) => {
                let result = run_job(&dir, &mut state, &job);
                let _ = job.reply.send(result);
            }
            Msg::InvalidateBase(base) => {
                if let Some(st) = state.as_mut() {
                    st.buffers.retain(|k, _| (k >> 20) != base);
                }
            }
        }
    }
}

fn run_job(dir: &Path, state: &mut Option<RtState>, job: &Job) -> Result<Vec<f64>> {
    if state.is_none() {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e:?}")))?;
        *state = Some(RtState { client, exes: HashMap::new(), buffers: HashMap::new() });
    }
    let st = state.as_mut().unwrap();

    if !st.exes.contains_key(&job.artifact) {
        let path = dir.join(format!("{}.hlo.txt", job.artifact));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("bad artifact path".into()))?,
        )
        .map_err(|e| Error::Runtime(format!("parse {}: {e:?}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = st
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {}: {e:?}", job.artifact)))?;
        st.exes.insert(job.artifact.clone(), exe);
    }

    // f32 artifacts (ablation) take converted inputs; everything else f64.
    let f32_mode = job.artifact.contains("_f32_");

    // Materialize missing cached buffers first (uploads happen once per
    // key), then run everything through execute_b on device buffers.
    for input in &job.inputs {
        if let JobInput::Cached { key, data, dims } = input {
            if f32_mode {
                return Err(Error::Runtime("cached inputs unsupported for f32 artifacts".into()));
            }
            if !st.buffers.contains_key(key) {
                let udims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
                let buf = st
                    .client
                    .buffer_from_host_buffer::<f64>(data, &udims, None)
                    .map_err(|e| Error::Runtime(format!("buffer upload: {e:?}")))?;
                st.buffers.insert(*key, buf);
            }
        }
    }

    let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
    let mut arg_refs: Vec<&xla::PjRtBuffer> = Vec::new();
    // two passes: build owned volatile buffers, then collect refs
    for input in &job.inputs {
        if let JobInput::Volatile(data, dims) = input {
            let udims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
            let buf = if f32_mode {
                let f32s: Vec<f32> = data.iter().map(|&x| x as f32).collect();
                st.client
                    .buffer_from_host_buffer::<f32>(&f32s, &udims, None)
                    .map_err(|e| Error::Runtime(format!("buffer upload: {e:?}")))?
            } else {
                st.client
                    .buffer_from_host_buffer::<f64>(data, &udims, None)
                    .map_err(|e| Error::Runtime(format!("buffer upload: {e:?}")))?
            };
            owned.push(buf);
        }
    }
    let mut owned_it = owned.iter();
    for input in &job.inputs {
        match input {
            JobInput::Volatile(..) => arg_refs.push(owned_it.next().unwrap()),
            JobInput::Cached { key, .. } => arg_refs.push(st.buffers.get(key).unwrap()),
        }
    }

    let exe = st.exes.get(&job.artifact).unwrap();
    let result = exe
        .execute_b::<&xla::PjRtBuffer>(&arg_refs)
        .map_err(|e| Error::Runtime(format!("execute {}: {e:?}", job.artifact)))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| Error::Runtime(format!("to_literal: {e:?}")))?;
    // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
    let out = lit.to_tuple1().map_err(|e| Error::Runtime(format!("to_tuple1: {e:?}")))?;
    if f32_mode {
        let v: Vec<f32> =
            out.to_vec().map_err(|e| Error::Runtime(format!("to_vec f32: {e:?}")))?;
        Ok(v.into_iter().map(|x| x as f64).collect())
    } else {
        out.to_vec().map_err(|e| Error::Runtime(format!("to_vec f64: {e:?}")))
    }
}

/// Lazily-started shared runtime keyed by artifacts dir, for call sites
/// that only have a `Config`.
pub fn runtime_from_config(cfg: &crate::config::ServerConfig) -> Result<&'static PjrtRuntime> {
    static BY_DIR: OnceLock<Mutex<HashMap<PathBuf, &'static PjrtRuntime>>> = OnceLock::new();
    let dir = PjrtRuntime::find_artifacts_dir(&cfg.artifacts_dir)?;
    let map = BY_DIR.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = map.lock().unwrap();
    if let Some(rt) = guard.get(&dir) {
        return Ok(rt);
    }
    let rt: &'static PjrtRuntime = Box::leak(Box::new(PjrtRuntime::start(&dir)?));
    guard.insert(dir, rt);
    Ok(rt)
}

pub use tiling::PjrtBackend;

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> &'static PjrtRuntime {
        let dir = PjrtRuntime::find_artifacts_dir("artifacts").expect("artifacts dir");
        PjrtRuntime::global(dir).expect("runtime")
    }

    #[test]
    fn gemm_acc_artifact_executes() {
        let rt = runtime();
        let t = 256usize;
        // A = I, B = 2I, acc = 3I  =>  out = 3I + 2I = 5I
        let mut eye = vec![0.0; t * t];
        let mut two = vec![0.0; t * t];
        let mut three = vec![0.0; t * t];
        for i in 0..t {
            eye[i * t + i] = 1.0;
            two[i * t + i] = 2.0;
            three[i * t + i] = 3.0;
        }
        let dims = vec![t as i64, t as i64];
        let out = rt
            .execute(
                "gemm_acc_f64_256",
                vec![(eye, dims.clone()), (two, dims.clone()), (three, dims)],
            )
            .unwrap();
        assert_eq!(out.len(), t * t);
        assert!((out[0] - 5.0).abs() < 1e-12);
        assert!((out[1]).abs() < 1e-12);
        assert!((out[t * t - 1] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn missing_artifact_is_error() {
        let rt = runtime();
        assert!(!rt.has_artifact("nope"));
        assert!(rt.execute("nope", vec![]).is_err());
    }

    #[test]
    fn gram_matvec_artifact_matches_native() {
        let rt = runtime();
        let (rows, n) = (1024usize, 256usize);
        let a = crate::workload::random_matrix(3, rows, n);
        let v: Vec<f64> = (0..n).map(|i| (i % 7) as f64 - 3.0).collect();
        let out = rt
            .execute(
                "gram_matvec_f64_1024x256",
                vec![
                    (a.clone(), vec![rows as i64, n as i64]),
                    (v.clone(), vec![n as i64, 1]),
                ],
            )
            .unwrap();
        // native reference
        let am = crate::linalg::DenseMatrix::from_vec(rows, n, a).unwrap();
        let t = am.matvec(&v).unwrap();
        let want = am.matvec_t(&t).unwrap();
        assert_eq!(out.len(), n);
        for (g, w) in out.iter().zip(&want) {
            assert!((g - w).abs() < 1e-8 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }
}
