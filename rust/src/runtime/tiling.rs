//! Tiling/padding glue: maps arbitrary panel shapes onto the fixed-shape
//! AOT artifacts.
//!
//! Artifacts are compiled once with static shapes (tile x tile); this
//! module pads edge blocks with zeros and loops the (i, j, l) tile space,
//! accumulating through the artifact's `acc` input — so a local GEMM of
//! any size is a sequence of identical PJRT executions with zero
//! recompilation. Padding is exact for GEMM: zero blocks contribute zero.

use std::sync::Arc;

use crate::elemental::dist_gemm::GemmBackend;
use crate::linalg::DenseMatrix;
use crate::runtime::{cache_key, JobInput, PjrtRuntime};
use crate::{Error, Result};

/// GEMM backend that routes node-local tile products through the PJRT
/// runtime (the L1 Pallas kernel inside the `gemm_acc_*` artifacts).
#[derive(Debug, Clone)]
pub struct PjrtBackend {
    rt: &'static PjrtRuntime,
    tile: usize,
    /// "f64" (default) or "f32" (ablation).
    dtype: &'static str,
}

impl PjrtBackend {
    pub fn new(rt: &'static PjrtRuntime, tile: usize) -> Result<PjrtBackend> {
        Self::with_dtype(rt, tile, "f64")
    }

    pub fn with_dtype(
        rt: &'static PjrtRuntime,
        tile: usize,
        dtype: &'static str,
    ) -> Result<PjrtBackend> {
        let b = PjrtBackend { rt, tile, dtype };
        if !rt.has_artifact(&b.artifact()) {
            return Err(Error::Runtime(format!(
                "artifact {} not exported (tile {tile}, dtype {dtype})",
                b.artifact()
            )));
        }
        Ok(b)
    }

    fn artifact(&self) -> String {
        format!("gemm_acc_{}_{}", self.dtype, self.tile)
    }

    pub fn tile(&self) -> usize {
        self.tile
    }
}

impl GemmBackend for PjrtBackend {
    fn gemm_acc(&self, a: &DenseMatrix, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        let (m, ka) = a.shape();
        let (kb, n) = b.shape();
        if ka != kb || c.shape() != (m, n) {
            return Err(Error::Shape(format!(
                "pjrt gemm: A {m}x{ka}, B {kb}x{n}, C {:?}",
                c.shape()
            )));
        }
        let t = self.tile;
        let dims = vec![t as i64, t as i64];
        let artifact = self.artifact();
        let tiles = |x: usize| (x + t - 1) / t;
        for bi in 0..tiles(m) {
            for bj in 0..tiles(n) {
                // accumulator tile starts as the current C block
                let mut acc = c.block_padded(bi * t, bj * t, t, t).into_vec();
                for bl in 0..tiles(ka) {
                    let a_blk = a.block_padded(bi * t, bl * t, t, t).into_vec();
                    let b_blk = b.block_padded(bl * t, bj * t, t, t).into_vec();
                    acc = self.rt.execute(
                        &artifact,
                        vec![(a_blk, dims.clone()), (b_blk, dims.clone()), (acc, dims.clone())],
                    )?;
                }
                let tile_mat = DenseMatrix::from_vec(t, t, acc)?;
                c.set_block(bi * t, bj * t, &tile_mat);
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        if self.dtype == "f32" {
            "pjrt-f32"
        } else {
            "pjrt"
        }
    }
}

/// A row panel pre-chunked onto the fused `gram_matvec` artifact's static
/// row tile, with each chunk **device-resident** under a cache key: the
/// panel is uploaded to PJRT once and every subsequent Lanczos iteration
/// only ships the (tiny) v vector. This is the production Gram-operator
/// path (EXPERIMENTS.md §Perf documents the win over per-call copies).
pub struct CachedGramPanel {
    artifact: String,
    rows_tile: usize,
    n: usize,
    /// (cache key, padded chunk data) per row chunk.
    chunks: Vec<(u64, Arc<Vec<f64>>)>,
}

impl CachedGramPanel {
    /// `base` must uniquely identify the panel process-wide (matrix
    /// handle); freeing the matrix should call
    /// `rt.invalidate_base(base)`.
    pub fn new(rt: &PjrtRuntime, base: u64, a: &DenseMatrix) -> Result<Option<CachedGramPanel>> {
        let (m, n) = a.shape();
        // below this, native kernels win (see pjrt_gram_matvec)
        if m * n < (1 << 19) {
            return Ok(None);
        }
        let candidates: &[usize] = if m <= 1024 { &[1024, 4096] } else { &[4096, 1024] };
        for &rows_tile in candidates {
            let artifact = format!("gram_matvec_f64_{rows_tile}x{n}");
            if !rt.has_artifact(&artifact) {
                continue;
            }
            let mut chunks = Vec::new();
            let mut r0 = 0usize;
            let mut idx = 0u64;
            while r0 < m {
                let blk = a.block_padded(r0, 0, rows_tile, n);
                chunks.push((cache_key(base, idx), Arc::new(blk.into_vec())));
                r0 += rows_tile;
                idx += 1;
            }
            return Ok(Some(CachedGramPanel { artifact, rows_tile, n, chunks }));
        }
        Ok(None) // no fused artifact for this width
    }

    /// w = Aᵀ(A v) over the cached chunks.
    pub fn apply(&self, rt: &PjrtRuntime, v: &[f64]) -> Result<Vec<f64>> {
        if v.len() != self.n {
            return Err(Error::Shape(format!("cached gram: v len {} vs {}", v.len(), self.n)));
        }
        let mut w = vec![0.0; self.n];
        for (key, data) in &self.chunks {
            let out = rt.execute_with(
                &self.artifact,
                vec![
                    JobInput::Cached {
                        key: *key,
                        data: data.clone(),
                        dims: vec![self.rows_tile as i64, self.n as i64],
                    },
                    JobInput::Volatile(v.to_vec(), vec![self.n as i64, 1]),
                ],
            )?;
            crate::linalg::blas1::axpy(1.0, &out, &mut w);
        }
        Ok(w)
    }
}

/// Gram matvec w = Aᵀ(A v) through PJRT, tiling A's rows over the fused
/// `gram_matvec` artifacts when an exact row-tile exists, otherwise
/// falling back to the gemv/gevm tile pair. `a` is the local row panel,
/// `v` has length `a.cols()`.
pub fn pjrt_gram_matvec(rt: &PjrtRuntime, a: &DenseMatrix, v: &[f64]) -> Result<Vec<f64>> {
    let (m, n) = a.shape();
    if v.len() != n {
        return Err(Error::Shape(format!("gram_matvec: v len {} vs cols {n}", v.len())));
    }
    // Small panels: PJRT per-call overhead (buffer copies + dispatch)
    // dwarfs the FLOPs — use the native kernels. Crossover measured in
    // EXPERIMENTS.md §Perf.
    if m * n < (1 << 19) {
        let t = a.matvec(v)?;
        return a.matvec_t(&t);
    }
    // Preferred: fused artifact with matching column count, row-tiled.
    // Pick the smallest exported row tile that covers the panel to cut
    // padding waste (1024 before 4096 for m <= 1024).
    let candidates: &[usize] = if m <= 1024 { &[1024, 4096] } else { &[4096, 1024] };
    for &rows_tile in candidates {
        let name = format!("gram_matvec_f64_{rows_tile}x{n}");
        if rt.has_artifact(&name) {
            let mut w = vec![0.0; n];
            let v_col: Vec<f64> = v.to_vec();
            let mut r0 = 0;
            while r0 < m {
                let blk = a.block_padded(r0, 0, rows_tile, n);
                let out = rt.execute(
                    &name,
                    vec![
                        (blk.into_vec(), vec![rows_tile as i64, n as i64]),
                        (v_col.clone(), vec![n as i64, 1]),
                    ],
                )?;
                crate::linalg::blas1::axpy(1.0, &out, &mut w);
                r0 += rows_tile;
            }
            return Ok(w);
        }
    }
    // Fallback: t = A v (gemv tiles), w = Aᵀ t (gevm tiles). Tile size
    // adapts to the panel so padding stays bounded.
    let tile = if m.max(n) <= 2048 { 256usize } else { 1024usize };
    let gemv_name = format!("gemv_acc_f64_{tile}");
    let gevm_name = format!("gevm_acc_f64_{tile}");
    let (gemv, gevm) = (gemv_name.as_str(), gevm_name.as_str());
    if !rt.has_artifact(gemv) || !rt.has_artifact(gevm) {
        return Err(Error::Runtime("no gemv/gevm artifacts exported".into()));
    }
    let t_dims = vec![tile as i64, tile as i64];
    let v_dims = vec![tile as i64, 1];
    let tiles = |x: usize| (x + tile - 1) / tile;

    // t = A v
    let mut tvec = vec![0.0; tiles(m) * tile];
    for bi in 0..tiles(m) {
        let mut acc = vec![0.0; tile];
        for bj in 0..tiles(n) {
            let a_blk = a.block_padded(bi * tile, bj * tile, tile, tile).into_vec();
            let mut v_blk = vec![0.0; tile];
            let upto = tile.min(n.saturating_sub(bj * tile));
            v_blk[..upto].copy_from_slice(&v[bj * tile..bj * tile + upto]);
            acc = rt.execute(
                gemv,
                vec![(a_blk, t_dims.clone()), (v_blk, v_dims.clone()), (acc, v_dims.clone())],
            )?;
        }
        tvec[bi * tile..(bi + 1) * tile].copy_from_slice(&acc);
    }

    // w = Aᵀ t
    let mut w = vec![0.0; n];
    for bj in 0..tiles(n) {
        let mut acc = vec![0.0; tile];
        for bi in 0..tiles(m) {
            let a_blk = a.block_padded(bi * tile, bj * tile, tile, tile).into_vec();
            let t_blk = tvec[bi * tile..(bi + 1) * tile].to_vec();
            acc = rt.execute(
                gevm,
                vec![(a_blk, t_dims.clone()), (t_blk, v_dims.clone()), (acc, v_dims.clone())],
            )?;
        }
        let upto = tile.min(n.saturating_sub(bj * tile));
        w[bj * tile..bj * tile + upto].copy_from_slice(&acc[..upto]);
    }
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::random_matrix;

    fn runtime() -> &'static PjrtRuntime {
        let dir = PjrtRuntime::find_artifacts_dir("artifacts").expect("artifacts dir");
        PjrtRuntime::global(dir).expect("runtime")
    }

    fn rand(seed: u64, r: usize, c: usize) -> DenseMatrix {
        DenseMatrix::from_vec(r, c, random_matrix(seed, r, c)).unwrap()
    }

    #[test]
    fn pjrt_gemm_matches_native_on_uneven_shapes() {
        let rt = runtime();
        let backend = PjrtBackend::new(rt, 256).unwrap();
        for (m, k, n) in [(100, 50, 30), (256, 256, 256), (300, 257, 120)] {
            let a = rand(1, m, k);
            let b = rand(2, k, n);
            let want = crate::linalg::gemm::gemm(&a, &b).unwrap();
            let got = backend.gemm(&a, &b).unwrap();
            assert!(got.max_abs_diff(&want).unwrap() < 1e-9, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn pjrt_gemm_acc_accumulates() {
        let rt = runtime();
        let backend = PjrtBackend::new(rt, 256).unwrap();
        let a = rand(3, 64, 64);
        let b = rand(4, 64, 64);
        let mut c = rand(5, 64, 64);
        let mut want = c.clone();
        crate::linalg::gemm::gemm_acc(&a, &b, &mut want).unwrap();
        backend.gemm_acc(&a, &b, &mut c).unwrap();
        assert!(c.max_abs_diff(&want).unwrap() < 1e-9);
    }

    #[test]
    fn f32_backend_is_less_precise_but_close() {
        let rt = runtime();
        let backend = PjrtBackend::with_dtype(rt, 256, "f32").unwrap();
        let a = rand(6, 64, 64);
        let b = rand(7, 64, 64);
        let want = crate::linalg::gemm::gemm(&a, &b).unwrap();
        let got = backend.gemm(&a, &b).unwrap();
        let diff = got.max_abs_diff(&want).unwrap();
        assert!(diff < 1e-3, "f32 diff {diff}");
        assert_eq!(backend.name(), "pjrt-f32");
    }

    #[test]
    fn gram_matvec_fused_path_matches_native() {
        let rt = runtime();
        // n=256 hits the fused gram artifacts; m not a tile multiple and
        // large enough to clear the native-kernel crossover.
        let a = rand(8, 3000, 256);
        let v: Vec<f64> = (0..256).map(|i| ((i * 13) % 11) as f64 - 5.0).collect();
        let t = a.matvec(&v).unwrap();
        let want = a.matvec_t(&t).unwrap();
        let got = pjrt_gram_matvec(rt, &a, &v).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-8 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn gram_matvec_fallback_path_matches_native() {
        let rt = runtime();
        // n=300: no fused artifact -> gemv/gevm tile pair (256 tiles).
        let a = rand(9, 2000, 300);
        let v: Vec<f64> = (0..300).map(|i| (i as f64).sin()).collect();
        let t = a.matvec(&v).unwrap();
        let want = a.matvec_t(&t).unwrap();
        let got = pjrt_gram_matvec(rt, &a, &v).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-8 * (1.0 + w.abs()));
        }
    }

    #[test]
    fn missing_tile_artifact_rejected() {
        let rt = runtime();
        assert!(PjrtBackend::new(rt, 999).is_err());
    }
}
