//! Configuration system: typed config structs, a minimal TOML-subset
//! parser (sections, scalar keys, comments) and `key=value` CLI overrides.
//!
//! Precedence: defaults < config file < `--set section.key=value` overrides.
//! Every bench/example accepts the same `--config`/`--set` surface, so the
//! whole harness is parameterized the way a deployable framework would be.

use std::collections::BTreeMap;
use std::path::Path;

use crate::{Error, Result};

/// Alchemist-server side knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// Number of Alchemist worker processes ("nodes" in the paper's grids).
    pub workers: u32,
    /// Rows per data-plane frame. 1 reproduces the paper's row-at-a-time
    /// behaviour; larger batches are the §Perf fix (see ablate_framing).
    pub batch_rows: u32,
    /// Directory holding the AOT artifacts (`*.hlo.txt` + manifest).
    pub artifacts_dir: String,
    /// "pjrt" (Pallas/XLA artifacts) or "native" (pure-Rust blocked GEMM).
    pub gemm_backend: String,
    /// Tile edge for the PJRT GEMM path (must match an exported artifact).
    pub gemm_tile: u32,
    /// Gram-operator backend for the SVD path: "native" (default on this
    /// CPU testbed — PJRT's per-execute dispatch (~6 ms) swamps a
    /// bandwidth-bound matvec; see EXPERIMENTS.md §Perf) or "pjrt" (the
    /// fused artifact + device-resident panels: the real-TPU production
    /// path, kept fully tested).
    pub svd_backend: String,
    /// TCP_NODELAY on data-plane sockets.
    pub nodelay: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 4,
            batch_rows: 256,
            artifacts_dir: "artifacts".into(),
            gemm_backend: "pjrt".into(),
            gemm_tile: 256,
            svd_backend: "native".into(),
            nodelay: true,
        }
    }
}

/// Compute-plane knobs (the distributed GEMM algorithm; see
/// `elemental/dist_gemm.rs` and DESIGN.md §Compute plane).
#[derive(Debug, Clone, PartialEq)]
pub struct ComputeConfig {
    /// Distributed GEMM algorithm: "ring" (ring-pipelined B-panel
    /// rotation with compute/comm overlap — the default), "allgather"
    /// (materialize full B per rank — the ablation baseline), or
    /// "summa2d" (true 2D SUMMA over a p_r × p_c process grid).
    pub dist_gemm_algo: String,
    /// Split each owned B panel into sub-panels of at most this many rows
    /// before shifting (finer overlap granularity, lower peak memory);
    /// 0 = shift whole owned panels. For summa2d this is the k-panel
    /// width (0 = ceil(k/p)).
    pub ring_panel_rows: u32,
    /// Process-grid shape for summa2d: "auto" (most-square factoring of
    /// the mesh size) or an explicit "RxC" such as "2x2". Ignored by the
    /// 1D algorithms.
    pub grid: String,
}

impl Default for ComputeConfig {
    fn default() -> Self {
        ComputeConfig { dist_gemm_algo: "ring".into(), ring_panel_rows: 0, grid: "auto".into() }
    }
}

impl ComputeConfig {
    /// Resolve into the typed options `dist_gemm_with` takes.
    pub fn dist_gemm_options(&self) -> Result<crate::elemental::dist_gemm::DistGemmOptions> {
        Ok(crate::elemental::dist_gemm::DistGemmOptions {
            algo: crate::elemental::dist_gemm::DistGemmAlgo::parse(&self.dist_gemm_algo)?,
            panel_rows: self.ring_panel_rows as usize,
            grid: crate::elemental::GridSpec::parse(&self.grid)?,
        })
    }
}

/// Data-plane transfer knobs (the client-side per-owner sender pipeline;
/// see `client/transfer.rs` and DESIGN.md §Data plane).
#[derive(Debug, Clone, PartialEq)]
pub struct TransferConfig {
    /// Sender threads per `push_rows` call. Owners are multiplexed
    /// round-robin across the threads when a matrix has more owners than
    /// threads; each owner's frames always go through exactly one thread
    /// (and one connection), preserving per-connection frame order.
    pub sender_threads: u32,
    /// Target payload bytes per data-plane frame: a routed batch flushes
    /// when it reaches this many value bytes or `batch_rows` rows,
    /// whichever comes first.
    pub slab_bytes: u32,
    /// Bounded depth of each sender pipeline channel — batches in flight
    /// per sender thread before the routing thread blocks (backpressure;
    /// stall time is recorded in `TransferMetrics`).
    pub channel_depth: u32,
    /// Data-plane transport selection: "auto" (the UDS fast path when a
    /// worker is co-located and advertises a socket path, TCP otherwise),
    /// "tcp", or "uds" (forced; dial errors if the worker has no path).
    pub transport: String,
    /// Data connections per owner. 1 = the classic single lane; higher
    /// values stripe slab batches round-robin over that many connections
    /// per owner (fat pipes). Capped at 16 by validation.
    pub stripes: u32,
    /// Wire codec for v9 sessions: "none", "delta" (lossless
    /// delta+varint packing, bit-identical roundtrip), or "f32" (lossy
    /// f64→f32 downcast — opt-in only, never auto-negotiated).
    pub compression: String,
}

impl Default for TransferConfig {
    fn default() -> Self {
        TransferConfig {
            sender_threads: 4,
            slab_bytes: 1 << 20,
            channel_depth: 4,
            transport: "auto".into(),
            stripes: 1,
            compression: "none".into(),
        }
    }
}

/// Sparklet (the Spark substitute) knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct SparkletConfig {
    /// Number of executors ("Spark nodes").
    pub executors: u32,
    /// Default number of partitions for new RDDs (Spark's
    /// `spark.default.parallelism`).
    pub default_parallelism: u32,
    /// Per-executor memory cap in MiB; shuffle blocks + cached partitions
    /// count against it and overflow aborts the job (Table 1's NA rows).
    pub executor_mem_mb: u64,
    /// BlockMatrix block edge (Spark's default is 1024).
    pub block_size: u32,
    /// Simulated per-task scheduling latency in microseconds. Loopback
    /// scheduling is ~free; real Spark pays O(ms) per task for closure
    /// serialization + RPC + JVM dispatch. Default is deliberately modest
    /// (200us ≈ optimistic Spark); set 0 to disable modeling entirely.
    pub task_overhead_us: u64,
}

impl Default for SparkletConfig {
    fn default() -> Self {
        SparkletConfig {
            executors: 4,
            default_parallelism: 8,
            executor_mem_mb: 512,
            block_size: 256,
            task_overhead_us: 200,
        }
    }
}

/// Driver scheduler knobs (the `sched` subsystem: queued admission +
/// async job queue).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedConfig {
    /// Per-session worker quota enforced by the allocator; 0 = unlimited.
    pub max_workers_per_session: u32,
    /// Cap on jobs submitted-but-not-finished per session (each inflight
    /// job holds a driver thread + retained result); 0 = unlimited.
    pub max_jobs_per_session: u32,
    /// Default time a `RequestWorkers { wait: true }` call may sit in the
    /// admission queue before the driver gives up (clients can override
    /// per request; 0 in the request means "use this default").
    pub wait_timeout_ms: u64,
    /// Server-side cap on how long one `WaitJob` round blocks the control
    /// connection; clients loop, so this only bounds per-poll latency.
    pub waitjob_block_ms: u64,
    /// Cost-aware admission: cap on the summed spec-derived cost
    /// (flops + bytes, see `ali::spec::CostEstimate::weight`) of one
    /// session's in-flight jobs. A submission that would push the sum
    /// over the cap is rejected at `SubmitRoutine` time — except the
    /// first job (an idle session always admits one job, so a cap below
    /// any single job's cost cannot brick the session). 0 = unlimited.
    /// Only spec-publishing libraries are counted (foreign ALIs cost 0).
    pub max_inflight_cost_per_session: f64,
    /// Pool recovery: how often the driver's health prober walks the
    /// quarantined workers (ping, drain stale replies, `Reset`, readmit).
    pub probe_interval_ms: u64,
    /// Pool recovery: per-I/O budget of one probe/reset exchange — a
    /// still-wedged worker fails its probe within this bound and stays
    /// quarantined until the next round.
    pub probe_timeout_ms: u64,
    /// QoS class assumed for sessions and jobs that do not name one:
    /// "interactive", "batch", or "best_effort" (protocol v11).
    pub default_class: String,
    /// Fair-share weights per class — a weight-8 class is offered ~8x
    /// the worker-grant throughput of a weight-1 class under contention.
    /// Must be >= 1.
    pub weight_interactive: u32,
    pub weight_batch: u32,
    pub weight_best_effort: u32,
    /// Allow small waiting requests to be granted out of order when they
    /// fit in currently-idle workers (bounded by the bypass limit so the
    /// skipped request cannot starve).
    pub backfill: bool,
    /// Allow a higher-priority `RequestWorkers { wait: true }` arrival to
    /// cancel-and-requeue the lowest-priority running job when the pool
    /// cannot cover it.
    pub preemption: bool,
    /// Upper bound on preemptions of any single job — victims always
    /// eventually finish.
    pub max_preemptions_per_job: u32,
    /// Preemption parking bound, MiB: a victim whose non-replicated
    /// matrices would park more than this much row data in driver
    /// memory across the regrant is skipped by the preemption scan
    /// (0 = unbounded). This is what keeps one giant tenant from
    /// OOMing the driver when it gets preempted.
    pub max_preempt_park_mb: u32,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            max_workers_per_session: 0,
            max_jobs_per_session: 1024,
            wait_timeout_ms: 30_000,
            waitjob_block_ms: 2_000,
            max_inflight_cost_per_session: 0.0,
            probe_interval_ms: 500,
            probe_timeout_ms: 1_000,
            default_class: "batch".into(),
            weight_interactive: 8,
            weight_batch: 4,
            weight_best_effort: 1,
            backfill: true,
            preemption: true,
            max_preemptions_per_job: 2,
            max_preempt_park_mb: 256,
        }
    }
}

/// Telemetry-plane knobs (metrics registry + span tracing; see
/// `telemetry/` and DESIGN.md §Telemetry plane).
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetryConfig {
    /// Master switch for span recording. Metrics counters always run
    /// (they are single relaxed atomic ops); disabling telemetry stops
    /// span buffer writes and turns `FetchTelemetry` replies span-free.
    pub enabled: bool,
    /// Span ring-buffer capacity per component (driver, each worker).
    /// Oldest spans are evicted — and counted — once the ring is full.
    pub span_buffer: u32,
    /// Record a data-plane span for every Nth slab frame a worker
    /// receives (0 = off, the default: per-frame spans are the one place
    /// tracing could touch a hot loop).
    pub sample_every: u32,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { enabled: true, span_buffer: 4096, sample_every: 0 }
    }
}

/// Bench-harness knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchConfig {
    /// Wall-clock budget per run, seconds (paper: 1800 s debug queue).
    pub budget_secs: u64,
    /// Linear scale factor applied to the paper's matrix dimensions
    /// (1.0 = the scaled-down defaults baked into each bench).
    pub scale: f64,
    /// Repetitions per configuration (paper: 3, averaged).
    pub reps: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { budget_secs: 120, scale: 1.0, reps: 2 }
    }
}

/// Deterministic fault-injection knobs (the `fault` subsystem; see
/// `fault/mod.rs` and DESIGN.md §Fault injection & client resilience).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Master switch. Off by default: a disabled plane is never even
    /// constructed, so production paths carry zero injection cost.
    pub enabled: bool,
    /// Seed for the per-site SplitMix64 streams — two runs with the same
    /// seed and schedule misbehave identically.
    pub seed: u64,
    /// Comma-separated injection schedule: `site:prob[:max_fires[:warmup]]`
    /// entries against the site catalog (`fault::SITE_CATALOG`) — warmup
    /// consults pass clean before the site arms, e.g.
    /// `"transport.disconnect:0.05:2,driver.drop_reply:1.0:1:4"`.
    pub sites: String,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig { enabled: false, seed: 1, sites: String::new() }
    }
}

/// Client-side retry/resume knobs (`client/transfer.rs` reconnect ladder
/// and the control-plane lost-reply resend).
#[derive(Debug, Clone, PartialEq)]
pub struct RetryConfig {
    /// Attempts per data-plane connection (1 = no retry). Each retry
    /// redials and resends only the slabs the worker has not acknowledged.
    pub max_attempts: u32,
    /// First backoff delay; doubles per attempt (with deterministic
    /// jitter in [0.5, 1.0] of the computed delay).
    pub backoff_base_ms: u64,
    /// Backoff ceiling.
    pub backoff_cap_ms: u64,
    /// Control-plane read timeout for lost-reply recovery: 0 (default)
    /// keeps the classic blocking behaviour; > 0 arms a read timeout and
    /// resends idempotent calls (nonce-carrying Submit, Poll/Wait) on the
    /// same connection. Only meaningful for v10 sessions under fault
    /// testing — a reply that is slow rather than lost would desync the
    /// call pairing, so leave this 0 outside chaos schedules.
    pub call_timeout_ms: u64,
}

impl Default for RetryConfig {
    fn default() -> Self {
        RetryConfig {
            max_attempts: 3,
            backoff_base_ms: 50,
            backoff_cap_ms: 2_000,
            call_timeout_ms: 0,
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub server: ServerConfig,
    pub sched: SchedConfig,
    pub compute: ComputeConfig,
    pub transfer: TransferConfig,
    pub sparklet: SparkletConfig,
    pub telemetry: TelemetryConfig,
    pub bench: BenchConfig,
    pub fault: FaultConfig,
    pub retry: RetryConfig,
}

/// A parsed `section.key -> raw string value` map.
type RawConfig = BTreeMap<String, String>;

fn parse_toml_subset(text: &str) -> Result<RawConfig> {
    let mut out = RawConfig::new();
    let mut section = String::new();
    for (lineno, raw_line) in text.lines().enumerate() {
        let line = raw_line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(Error::Config(format!("line {}: expected key = value", lineno + 1)));
        };
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let val = v.trim().trim_matches('"').to_string();
        out.insert(key, val);
    }
    Ok(out)
}

fn apply_raw(cfg: &mut Config, raw: &RawConfig) -> Result<()> {
    for (key, val) in raw {
        apply_one(cfg, key, val)?;
    }
    Ok(())
}

fn parse<T: std::str::FromStr>(key: &str, val: &str) -> Result<T> {
    val.parse()
        .map_err(|_| Error::Config(format!("bad value for {key}: {val:?}")))
}

fn apply_one(cfg: &mut Config, key: &str, val: &str) -> Result<()> {
    match key {
        "server.workers" => cfg.server.workers = parse(key, val)?,
        "server.batch_rows" => cfg.server.batch_rows = parse(key, val)?,
        "server.artifacts_dir" => cfg.server.artifacts_dir = val.to_string(),
        "server.gemm_backend" => {
            if val != "pjrt" && val != "native" {
                return Err(Error::Config(format!("gemm_backend must be pjrt|native, got {val}")));
            }
            cfg.server.gemm_backend = val.to_string();
        }
        "server.gemm_tile" => cfg.server.gemm_tile = parse(key, val)?,
        "server.svd_backend" => {
            if val != "pjrt" && val != "native" {
                return Err(Error::Config(format!("svd_backend must be pjrt|native, got {val}")));
            }
            cfg.server.svd_backend = val.to_string();
        }
        "server.nodelay" => cfg.server.nodelay = parse(key, val)?,
        "sched.max_workers_per_session" => {
            cfg.sched.max_workers_per_session = parse(key, val)?
        }
        "sched.max_jobs_per_session" => cfg.sched.max_jobs_per_session = parse(key, val)?,
        "sched.wait_timeout_ms" => cfg.sched.wait_timeout_ms = parse(key, val)?,
        "sched.waitjob_block_ms" => cfg.sched.waitjob_block_ms = parse(key, val)?,
        "sched.max_inflight_cost_per_session" => {
            cfg.sched.max_inflight_cost_per_session = parse(key, val)?
        }
        "sched.probe_interval_ms" => cfg.sched.probe_interval_ms = parse(key, val)?,
        "sched.probe_timeout_ms" => cfg.sched.probe_timeout_ms = parse(key, val)?,
        "sched.default_class" => {
            crate::protocol::QosClass::parse(val)?;
            cfg.sched.default_class = val.to_string();
        }
        "sched.weight_interactive" => cfg.sched.weight_interactive = parse(key, val)?,
        "sched.weight_batch" => cfg.sched.weight_batch = parse(key, val)?,
        "sched.weight_best_effort" => cfg.sched.weight_best_effort = parse(key, val)?,
        "sched.backfill" => cfg.sched.backfill = parse(key, val)?,
        "sched.preemption" => cfg.sched.preemption = parse(key, val)?,
        "sched.max_preemptions_per_job" => cfg.sched.max_preemptions_per_job = parse(key, val)?,
        "sched.max_preempt_park_mb" => cfg.sched.max_preempt_park_mb = parse(key, val)?,
        "compute.dist_gemm_algo" => {
            crate::elemental::dist_gemm::DistGemmAlgo::parse(val)?;
            cfg.compute.dist_gemm_algo = val.to_string();
        }
        "compute.ring_panel_rows" => cfg.compute.ring_panel_rows = parse(key, val)?,
        "compute.grid" => {
            crate::elemental::GridSpec::parse(val)?;
            cfg.compute.grid = val.to_string();
        }
        "transfer.sender_threads" => cfg.transfer.sender_threads = parse(key, val)?,
        "transfer.slab_bytes" => cfg.transfer.slab_bytes = parse(key, val)?,
        "transfer.channel_depth" => cfg.transfer.channel_depth = parse(key, val)?,
        "transfer.transport" => {
            crate::transport::TransportChoice::parse(val)?;
            cfg.transfer.transport = val.to_string();
        }
        "transfer.stripes" => cfg.transfer.stripes = parse(key, val)?,
        "transfer.compression" => {
            crate::protocol::WireCodec::parse(val)?;
            cfg.transfer.compression = val.to_string();
        }
        "sparklet.executors" => cfg.sparklet.executors = parse(key, val)?,
        "sparklet.default_parallelism" => cfg.sparklet.default_parallelism = parse(key, val)?,
        "sparklet.executor_mem_mb" => cfg.sparklet.executor_mem_mb = parse(key, val)?,
        "sparklet.block_size" => cfg.sparklet.block_size = parse(key, val)?,
        "sparklet.task_overhead_us" => cfg.sparklet.task_overhead_us = parse(key, val)?,
        "telemetry.enabled" => cfg.telemetry.enabled = parse(key, val)?,
        "telemetry.span_buffer" => cfg.telemetry.span_buffer = parse(key, val)?,
        "telemetry.sample_every" => cfg.telemetry.sample_every = parse(key, val)?,
        "bench.budget_secs" => cfg.bench.budget_secs = parse(key, val)?,
        "bench.scale" => cfg.bench.scale = parse(key, val)?,
        "bench.reps" => cfg.bench.reps = parse(key, val)?,
        "fault.enabled" => cfg.fault.enabled = parse(key, val)?,
        "fault.seed" => cfg.fault.seed = parse(key, val)?,
        "fault.sites" => {
            crate::fault::parse_sites(val)?;
            cfg.fault.sites = val.to_string();
        }
        "retry.max_attempts" => cfg.retry.max_attempts = parse(key, val)?,
        "retry.backoff_base_ms" => cfg.retry.backoff_base_ms = parse(key, val)?,
        "retry.backoff_cap_ms" => cfg.retry.backoff_cap_ms = parse(key, val)?,
        "retry.call_timeout_ms" => cfg.retry.call_timeout_ms = parse(key, val)?,
        _ => return Err(Error::Config(format!("unknown config key: {key}"))),
    }
    Ok(())
}

impl Config {
    /// Load from a config file (TOML subset). Missing file is an error;
    /// use `Config::default()` + overrides when no file is wanted.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())?;
        let mut cfg = Config::default();
        apply_raw(&mut cfg, &parse_toml_subset(&text)?)?;
        Ok(cfg)
    }

    /// Apply `section.key=value` CLI overrides.
    pub fn apply_overrides<S: AsRef<str>>(&mut self, overrides: &[S]) -> Result<()> {
        for o in overrides {
            let s = o.as_ref();
            let Some((k, v)) = s.split_once('=') else {
                return Err(Error::Config(format!("override must be key=value: {s:?}")));
            };
            apply_one(self, k.trim(), v.trim())?;
        }
        Ok(())
    }

    /// Default config + optional file + overrides — the standard entry
    /// point used by `main.rs`, examples and benches.
    pub fn resolve(file: Option<&str>, overrides: &[String]) -> Result<Config> {
        let mut cfg = match file {
            Some(f) => Config::from_file(f)?,
            None => Config::default(),
        };
        cfg.apply_overrides(overrides)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.server.workers == 0 {
            return Err(Error::Config("server.workers must be >= 1".into()));
        }
        if self.server.batch_rows == 0 {
            return Err(Error::Config("server.batch_rows must be >= 1".into()));
        }
        if self.sparklet.executors == 0 {
            return Err(Error::Config("sparklet.executors must be >= 1".into()));
        }
        if !(self.bench.scale > 0.0) {
            return Err(Error::Config("bench.scale must be > 0".into()));
        }
        if self.sched.waitjob_block_ms == 0 {
            return Err(Error::Config("sched.waitjob_block_ms must be >= 1".into()));
        }
        if self.sched.wait_timeout_ms == 0 {
            return Err(Error::Config("sched.wait_timeout_ms must be >= 1".into()));
        }
        if self.sched.probe_interval_ms == 0 {
            return Err(Error::Config("sched.probe_interval_ms must be >= 1".into()));
        }
        if self.sched.probe_timeout_ms == 0 {
            return Err(Error::Config("sched.probe_timeout_ms must be >= 1".into()));
        }
        if !self.sched.max_inflight_cost_per_session.is_finite()
            || self.sched.max_inflight_cost_per_session < 0.0
        {
            return Err(Error::Config(
                "sched.max_inflight_cost_per_session must be finite and >= 0".into(),
            ));
        }
        // re-validate in case the struct was mutated directly
        crate::protocol::QosClass::parse(&self.sched.default_class)?;
        if self.sched.weight_interactive == 0
            || self.sched.weight_batch == 0
            || self.sched.weight_best_effort == 0
        {
            return Err(Error::Config("sched QoS class weights must be >= 1".into()));
        }
        // re-validate in case the struct was mutated directly
        crate::elemental::dist_gemm::DistGemmAlgo::parse(&self.compute.dist_gemm_algo)?;
        crate::elemental::GridSpec::parse(&self.compute.grid)?;
        if self.transfer.sender_threads == 0 {
            return Err(Error::Config("transfer.sender_threads must be >= 1".into()));
        }
        if self.transfer.channel_depth == 0 {
            return Err(Error::Config("transfer.channel_depth must be >= 1".into()));
        }
        if self.transfer.slab_bytes < 64 {
            return Err(Error::Config("transfer.slab_bytes must be >= 64".into()));
        }
        // Leave generous headroom under the frame cap for the index
        // array + message header, so a validated config can never produce
        // a "frame too large" error mid-transfer.
        if self.transfer.slab_bytes as usize > crate::protocol::MAX_FRAME_BYTES / 2 {
            return Err(Error::Config(format!(
                "transfer.slab_bytes must be <= {} (half the frame cap)",
                crate::protocol::MAX_FRAME_BYTES / 2
            )));
        }
        if !(1..=16).contains(&self.transfer.stripes) {
            return Err(Error::Config("transfer.stripes must be in [1, 16]".into()));
        }
        // re-validate in case the struct was mutated directly
        crate::transport::TransportChoice::parse(&self.transfer.transport)?;
        crate::protocol::WireCodec::parse(&self.transfer.compression)?;
        if !(16..=1 << 20).contains(&self.telemetry.span_buffer) {
            return Err(Error::Config("telemetry.span_buffer must be in [16, 2^20]".into()));
        }
        // re-validate in case the struct was mutated directly
        crate::fault::parse_sites(&self.fault.sites)?;
        if self.retry.max_attempts == 0 {
            return Err(Error::Config("retry.max_attempts must be >= 1".into()));
        }
        if self.retry.backoff_base_ms == 0 {
            return Err(Error::Config("retry.backoff_base_ms must be >= 1".into()));
        }
        if self.retry.backoff_cap_ms < self.retry.backoff_base_ms {
            return Err(Error::Config(
                "retry.backoff_cap_ms must be >= retry.backoff_base_ms".into(),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn parse_file_subset() {
        let text = r#"
# comment
[server]
workers = 8
gemm_backend = "native"   # inline comment
nodelay = false

[sparklet]
executors = 22
executor_mem_mb = 1024

[bench]
scale = 0.5
"#;
        let raw = parse_toml_subset(text).unwrap();
        let mut cfg = Config::default();
        apply_raw(&mut cfg, &raw).unwrap();
        assert_eq!(cfg.server.workers, 8);
        assert_eq!(cfg.server.gemm_backend, "native");
        assert!(!cfg.server.nodelay);
        assert_eq!(cfg.sparklet.executors, 22);
        assert_eq!(cfg.bench.scale, 0.5);
    }

    #[test]
    fn sched_keys_parse_and_validate() {
        let mut cfg = Config::default();
        cfg.apply_overrides(&[
            "sched.max_workers_per_session=2",
            "sched.max_jobs_per_session=8",
            "sched.wait_timeout_ms=500",
            "sched.waitjob_block_ms=100",
            "sched.max_inflight_cost_per_session=1e9",
            "sched.probe_interval_ms=50",
            "sched.probe_timeout_ms=250",
            "sched.default_class=interactive",
            "sched.weight_interactive=16",
            "sched.weight_batch=3",
            "sched.weight_best_effort=2",
            "sched.backfill=false",
            "sched.preemption=false",
            "sched.max_preemptions_per_job=5",
            "sched.max_preempt_park_mb=64",
        ])
        .unwrap();
        assert_eq!(cfg.sched.max_workers_per_session, 2);
        assert_eq!(cfg.sched.max_jobs_per_session, 8);
        assert_eq!(cfg.sched.wait_timeout_ms, 500);
        assert_eq!(cfg.sched.waitjob_block_ms, 100);
        assert_eq!(cfg.sched.max_inflight_cost_per_session, 1e9);
        assert_eq!(cfg.sched.probe_interval_ms, 50);
        assert_eq!(cfg.sched.probe_timeout_ms, 250);
        assert_eq!(cfg.sched.default_class, "interactive");
        assert_eq!(cfg.sched.weight_interactive, 16);
        assert_eq!(cfg.sched.weight_batch, 3);
        assert_eq!(cfg.sched.weight_best_effort, 2);
        assert!(!cfg.sched.backfill);
        assert!(!cfg.sched.preemption);
        assert_eq!(cfg.sched.max_preemptions_per_job, 5);
        assert_eq!(cfg.sched.max_preempt_park_mb, 64);
        cfg.validate().unwrap();
        // unknown classes are rejected at apply time...
        assert!(cfg.apply_overrides(&["sched.default_class=platinum"]).is_err());
        // ...and direct struct mutation is caught by validate.
        cfg.sched.default_class = "platinum".into();
        assert!(cfg.validate().is_err());
        cfg.sched.default_class = "batch".into();
        cfg.sched.weight_batch = 0;
        assert!(cfg.validate().is_err());
        cfg.sched.weight_batch = 4;
        cfg.sched.max_inflight_cost_per_session = -1.0;
        assert!(cfg.validate().is_err());
        cfg.sched.max_inflight_cost_per_session = 0.0;
        cfg.sched.waitjob_block_ms = 0;
        assert!(cfg.validate().is_err());
        cfg.sched.waitjob_block_ms = 1;
        cfg.sched.probe_interval_ms = 0;
        assert!(cfg.validate().is_err());
        cfg.sched.probe_interval_ms = 1;
        cfg.sched.probe_timeout_ms = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn compute_keys_parse_and_validate() {
        let mut cfg = Config::default();
        assert_eq!(cfg.compute.dist_gemm_algo, "ring");
        cfg.apply_overrides(&["compute.dist_gemm_algo=allgather", "compute.ring_panel_rows=32"])
            .unwrap();
        assert_eq!(cfg.compute.dist_gemm_algo, "allgather");
        assert_eq!(cfg.compute.ring_panel_rows, 32);
        let opts = cfg.compute.dist_gemm_options().unwrap();
        assert_eq!(opts.algo, crate::elemental::dist_gemm::DistGemmAlgo::AllGatherB);
        assert_eq!(opts.panel_rows, 32);
        assert!(cfg.apply_overrides(&["compute.dist_gemm_algo=summa3d"]).is_err());
        cfg.compute.dist_gemm_algo = "bogus".into();
        assert!(cfg.validate().is_err());
        // summa2d + explicit grid
        let mut cfg = Config::default();
        assert_eq!(cfg.compute.grid, "auto");
        cfg.apply_overrides(&["compute.dist_gemm_algo=summa2d", "compute.grid=2x2"]).unwrap();
        let opts = cfg.compute.dist_gemm_options().unwrap();
        assert_eq!(opts.algo, crate::elemental::dist_gemm::DistGemmAlgo::Summa2D);
        assert_eq!(opts.grid, crate::elemental::GridSpec::Fixed(2, 2));
        assert!(cfg.apply_overrides(&["compute.grid=0x3"]).is_err());
        assert!(cfg.apply_overrides(&["compute.grid=banana"]).is_err());
        cfg.compute.grid = "3x".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn transfer_keys_parse_and_validate() {
        let mut cfg = Config::default();
        assert_eq!(cfg.transfer.transport, "auto");
        assert_eq!(cfg.transfer.stripes, 1);
        assert_eq!(cfg.transfer.compression, "none");
        cfg.apply_overrides(&[
            "transfer.sender_threads=8",
            "transfer.slab_bytes=65536",
            "transfer.channel_depth=2",
            "transfer.transport=uds",
            "transfer.stripes=4",
            "transfer.compression=delta",
        ])
        .unwrap();
        assert_eq!(cfg.transfer.sender_threads, 8);
        assert_eq!(cfg.transfer.slab_bytes, 65536);
        assert_eq!(cfg.transfer.channel_depth, 2);
        assert_eq!(cfg.transfer.transport, "uds");
        assert_eq!(cfg.transfer.stripes, 4);
        assert_eq!(cfg.transfer.compression, "delta");
        cfg.validate().unwrap();
        // unknown enum values are rejected at apply time
        assert!(cfg.apply_overrides(&["transfer.transport=rdma"]).is_err());
        assert!(cfg.apply_overrides(&["transfer.compression=lz4"]).is_err());
        // zero / out-of-range numerics are typed config errors
        cfg.transfer.sender_threads = 0;
        assert!(cfg.validate().is_err());
        cfg.transfer.sender_threads = 1;
        cfg.transfer.channel_depth = 0;
        assert!(cfg.validate().is_err());
        cfg.transfer.channel_depth = 1;
        cfg.transfer.slab_bytes = 8;
        assert!(cfg.validate().is_err());
        cfg.transfer.slab_bytes = u32::MAX; // above the frame-cap headroom
        assert!(cfg.validate().is_err());
        cfg.transfer.slab_bytes = 65536;
        cfg.transfer.stripes = 0;
        assert!(cfg.validate().is_err());
        cfg.transfer.stripes = 17;
        assert!(cfg.validate().is_err());
        cfg.transfer.stripes = 16;
        cfg.validate().unwrap();
        // direct struct mutation is caught by validate too
        cfg.transfer.transport = "bogus".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn telemetry_keys_parse_and_validate() {
        let mut cfg = Config::default();
        assert!(cfg.telemetry.enabled);
        assert_eq!(cfg.telemetry.span_buffer, 4096);
        assert_eq!(cfg.telemetry.sample_every, 0);
        cfg.apply_overrides(&[
            "telemetry.enabled=false",
            "telemetry.span_buffer=128",
            "telemetry.sample_every=64",
        ])
        .unwrap();
        assert!(!cfg.telemetry.enabled);
        assert_eq!(cfg.telemetry.span_buffer, 128);
        assert_eq!(cfg.telemetry.sample_every, 64);
        cfg.validate().unwrap();
        cfg.telemetry.span_buffer = 8;
        assert!(cfg.validate().is_err());
        cfg.telemetry.span_buffer = (1 << 20) + 1;
        assert!(cfg.validate().is_err());

        let text = "[telemetry]\nenabled = true\nspan_buffer = 256\n";
        let raw = parse_toml_subset(text).unwrap();
        let mut cfg = Config::default();
        apply_raw(&mut cfg, &raw).unwrap();
        assert_eq!(cfg.telemetry.span_buffer, 256);
    }

    #[test]
    fn fault_and_retry_keys_parse_and_validate() {
        let mut cfg = Config::default();
        assert!(!cfg.fault.enabled);
        assert_eq!(cfg.fault.seed, 1);
        assert!(cfg.fault.sites.is_empty());
        assert_eq!(cfg.retry.max_attempts, 3);
        assert_eq!(cfg.retry.call_timeout_ms, 0);
        cfg.apply_overrides(&[
            "fault.enabled=true",
            "fault.seed=42",
            "fault.sites=transport.disconnect:0.1:2,driver.drop_reply:1.0:1",
            "retry.max_attempts=5",
            "retry.backoff_base_ms=10",
            "retry.backoff_cap_ms=500",
            "retry.call_timeout_ms=250",
        ])
        .unwrap();
        assert!(cfg.fault.enabled);
        assert_eq!(cfg.fault.seed, 42);
        assert_eq!(cfg.retry.max_attempts, 5);
        assert_eq!(cfg.retry.backoff_base_ms, 10);
        assert_eq!(cfg.retry.backoff_cap_ms, 500);
        assert_eq!(cfg.retry.call_timeout_ms, 250);
        cfg.validate().unwrap();
        // unknown sites and malformed schedules are rejected at apply time
        assert!(cfg.apply_overrides(&["fault.sites=transport.warp:0.5"]).is_err());
        assert!(cfg.apply_overrides(&["fault.sites=transport.dial:2.0"]).is_err());
        // direct struct mutation is caught by validate
        cfg.fault.sites = "bogus:1.0".into();
        assert!(cfg.validate().is_err());
        cfg.fault.sites = String::new();
        cfg.retry.max_attempts = 0;
        assert!(cfg.validate().is_err());
        cfg.retry.max_attempts = 1;
        cfg.retry.backoff_base_ms = 0;
        assert!(cfg.validate().is_err());
        cfg.retry.backoff_base_ms = 100;
        cfg.retry.backoff_cap_ms = 50;
        assert!(cfg.validate().is_err());
        cfg.retry.backoff_cap_ms = 100;
        cfg.validate().unwrap();

        let text = "[fault]\nenabled = true\nseed = 7\nsites = \"transport.stall:0.5\"\n\
                    \n[retry]\nmax_attempts = 2\n";
        let raw = parse_toml_subset(text).unwrap();
        let mut cfg = Config::default();
        apply_raw(&mut cfg, &raw).unwrap();
        assert!(cfg.fault.enabled);
        assert_eq!(cfg.fault.seed, 7);
        assert_eq!(cfg.fault.sites, "transport.stall:0.5");
        assert_eq!(cfg.retry.max_attempts, 2);
    }

    #[test]
    fn overrides_apply_and_validate() {
        let mut cfg = Config::default();
        cfg.apply_overrides(&["server.workers=16", "bench.reps=1"]).unwrap();
        assert_eq!(cfg.server.workers, 16);
        assert_eq!(cfg.bench.reps, 1);
        assert!(cfg.apply_overrides(&["nope.key=1"]).is_err());
        assert!(cfg.apply_overrides(&["server.workers"]).is_err());
        assert!(cfg.apply_overrides(&["server.gemm_backend=cuda"]).is_err());
    }

    #[test]
    fn bad_values_rejected() {
        let mut cfg = Config::default();
        assert!(cfg.apply_overrides(&["server.workers=banana"]).is_err());
        cfg.server.workers = 0;
        assert!(cfg.validate().is_err());
    }
}
