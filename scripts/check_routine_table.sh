#!/usr/bin/env bash
# CI drift check: the routine table embedded in rust/README.md must match
# what the registry actually publishes (`cargo run --example
# describe_routines`). Regenerate the README block with:
#   cd rust && cargo run --quiet --release --example describe_routines
set -euo pipefail
cd "$(dirname "$0")/../rust"

generated=$(mktemp)
embedded=$(mktemp)
trap 'rm -f "$generated" "$embedded"' EXIT

cargo run --quiet --release --example describe_routines > "$generated"
awk '/<!-- routine-table:begin -->/{f=1;next} /<!-- routine-table:end -->/{f=0} f' \
    README.md > "$embedded"

if ! diff -u "$embedded" "$generated"; then
    echo "rust/README.md routine table drifted from the RoutineRegistry." >&2
    echo "Regenerate it: cd rust && cargo run --example describe_routines" >&2
    exit 1
fi
echo "routine table in sync with the registry"
