#!/usr/bin/env bash
# Snapshot the perf-trajectory benchmarks into a single JSON file
# (BENCH_PR10.json at the repo root).
#
# Runs table1_matmul (ring vs all-gather compute decomposition + the
# Spark comparison), ablate_collectives (all-reduce + barrier),
# ablate_scheduler (submission disciplines + the pool_recovery and
# PR 8 fault_storm fault-injection scenarios + the PR 10 mixed_tenant
# QoS scenario: per-class p50/p99 queue wait, v11 policy vs v10 FIFO),
# and the table2/table3 transfer benches
# (node grid + the PR 7 transport x compression sweep: tcp / uds /
# striped-N x none / delta / f32), and ablate_gemm_backend (the PR 9
# summa2d process-grid sweep), each with its machine-readable
# --json output, then captures a live telemetry snapshot (merged
# registry + span timeline) from a headless alchemist_top run, and
# merges everything.
#
# Usage: scripts/bench_snapshot.sh [output.json]
#   env: REPS=N        bench.reps override (default 1 for a quick pass)
#        BUDGET_SECS=N spark-side budget (default 120)
set -euo pipefail

OUT="${1:-BENCH_PR10.json}"
REPS="${REPS:-1}"
BUDGET_SECS="${BUDGET_SECS:-120}"

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
cd "$ROOT/rust"

TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT

echo "== bench_snapshot: table1_matmul (reps=$REPS) =="
cargo bench --bench table1_matmul -- \
    --set "bench.reps=$REPS" --set "bench.budget_secs=$BUDGET_SECS" \
    --json "$TMP/table1.json"

echo "== bench_snapshot: ablate_collectives (reps=$REPS) =="
cargo bench --bench ablate_collectives -- \
    --set "bench.reps=$REPS" \
    --json "$TMP/collectives.json"

echo "== bench_snapshot: ablate_scheduler + pool_recovery + fault_storm + mixed_tenant (reps=$REPS) =="
cargo bench --bench ablate_scheduler -- \
    --set "bench.reps=$REPS" \
    --json "$TMP/scheduler.json"

echo "== bench_snapshot: table2_transfer_tall + transport sweep (reps=$REPS) =="
cargo bench --bench table2_transfer_tall -- \
    --set "bench.reps=$REPS" \
    --json "$TMP/transfer_tall.json"

echo "== bench_snapshot: table3_transfer_wide + transport sweep (reps=$REPS) =="
cargo bench --bench table3_transfer_wide -- \
    --set "bench.reps=$REPS" \
    --json "$TMP/transfer_wide.json"

echo "== bench_snapshot: ablate_gemm_backend + grid sweep (reps=$REPS) =="
cargo bench --bench ablate_gemm_backend -- \
    --set "bench.reps=$REPS" \
    --json "$TMP/gemm_backend.json"

echo "== bench_snapshot: telemetry snapshot (alchemist_top --headless) =="
cargo run --release --example alchemist_top -- \
    --headless --jobs 4 --snapshot-json "$TMP/telemetry.json"

GIT_SHA="$(git -C "$ROOT" rev-parse --short HEAD 2>/dev/null || echo unknown)"
DATE="$(date -u +%Y-%m-%dT%H:%M:%SZ)"

{
    printf '{\n'
    printf '  "generated_at": "%s",\n' "$DATE"
    printf '  "git": "%s",\n' "$GIT_SHA"
    printf '  "reps": %s,\n' "$REPS"
    printf '  "table1_matmul": %s,\n' "$(cat "$TMP/table1.json")"
    printf '  "ablate_collectives": %s,\n' "$(cat "$TMP/collectives.json")"
    printf '  "ablate_scheduler": %s,\n' "$(cat "$TMP/scheduler.json")"
    printf '  "table2_transfer_tall": %s,\n' "$(cat "$TMP/transfer_tall.json")"
    printf '  "table3_transfer_wide": %s,\n' "$(cat "$TMP/transfer_wide.json")"
    printf '  "ablate_gemm_backend": %s,\n' "$(cat "$TMP/gemm_backend.json")"
    printf '  "telemetry": %s\n' "$(cat "$TMP/telemetry.json")"
    printf '}\n'
} > "$ROOT/$OUT"

echo "wrote $ROOT/$OUT"
