#!/usr/bin/env python3
"""Validate the shape of the checked-in BENCH_PR*.json snapshots.

The perf trajectory lives in these files (one per PR that moved it), and
downstream tooling reads them blindly, so CI checks every snapshot —
whether a schema seed full of nulls or a populated run from
scripts/bench_snapshot.sh — against the row shapes the bench --json
emitters (and, since PR 6, TelemetryReport::to_json) actually produce.
Values may be null (seed) or numbers (populated); *missing or misnamed
keys* are what this catches.

Usage: python3 scripts/validate_bench_json.py [FILE ...]
       (no args: validates every BENCH_PR*.json at the repo root)

Stdlib only; exits non-zero listing every problem found.
"""

import glob
import json
import os
import sys

NUM = (int, float)


def is_num_or_null(v):
    return v is None or (isinstance(v, NUM) and not isinstance(v, bool))


class Checker:
    def __init__(self, path):
        self.path = path
        self.errors = []

    def err(self, where, msg):
        self.errors.append(f"{self.path}: {where}: {msg}")

    def require_keys(self, obj, keys, where):
        if not isinstance(obj, dict):
            self.err(where, f"expected object, got {type(obj).__name__}")
            return False
        missing = [k for k in keys if k not in obj]
        if missing:
            self.err(where, f"missing keys {missing} (has {sorted(obj)})")
        return not missing

    def rows(self, doc, section, required_keys, numeric_keys):
        """A section must be a list of objects with the given keys."""
        rows = doc.get(section)
        if not isinstance(rows, list) or not rows:
            self.err(section, "expected a non-empty array of rows")
            return
        for i, row in enumerate(rows):
            where = f"{section}[{i}]"
            if not self.require_keys(row, required_keys, where):
                continue
            for k in numeric_keys:
                if k in row and not is_num_or_null(row[k]):
                    self.err(where, f"{k!r} should be a number or null, got {row[k]!r}")

    def telemetry(self, doc):
        """The merged v8 snapshot (TelemetryReport::to_json shape)."""
        tel = doc.get("telemetry")
        if not self.require_keys(tel, ["counters", "gauges", "phases", "spans"], "telemetry"):
            return
        for section in ("counters", "gauges"):
            vals = tel[section]
            if not isinstance(vals, dict):
                self.err(f"telemetry.{section}", "expected an object")
                continue
            for k, v in vals.items():
                if not is_num_or_null(v):
                    self.err(f"telemetry.{section}.{k}", f"expected number or null, got {v!r}")
        if isinstance(tel["phases"], dict):
            for k, v in tel["phases"].items():
                self.require_keys(v, ["secs", "count"], f"telemetry.phases.{k}")
        else:
            self.err("telemetry.phases", "expected an object")
        if isinstance(tel["spans"], list):
            for i, span in enumerate(tel["spans"]):
                self.require_keys(
                    span,
                    ["trace_id", "name", "source", "start_us", "dur_us"],
                    f"telemetry.spans[{i}]",
                )
        else:
            self.err("telemetry.spans", "expected an array")

    def run(self):
        try:
            with open(self.path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            self.err("parse", str(e))
            return self.errors
        self.require_keys(doc, ["generated_at", "git", "reps"], "top-level")
        if not isinstance(doc, dict):
            return self.errors

        self.rows(
            doc,
            "table1_matmul",
            ["m", "n", "k", "nodes", "send_s", "recv_s"],
            ["m", "n", "k", "nodes", "send_s", "ring_compute_s", "allgather_compute_s", "recv_s"],
        )
        for i, row in enumerate(doc.get("ablate_collectives") or []):
            where = f"ablate_collectives[{i}]"
            if not isinstance(row, dict) or "ranks" not in row:
                self.err(where, "row needs a 'ranks' key")
            elif not any(k in row for k in ("naive_ms", "ring_ms", "barrier_us")):
                self.err(where, "row needs naive_ms/ring_ms or barrier_us")
        # Sections that joined the trajectory later are validated only
        # when present, so older snapshots (PR3...) stay green.
        if "ablate_scheduler" in doc:
            self.rows(doc, "ablate_scheduler", ["scenario"], ["secs", "jobs_per_s", "recovery_ms"])
            # PR 8: the fault_storm scenario reports chaos survival plus
            # the post-storm pool heal time.
            storms = [
                r
                for r in doc["ablate_scheduler"] or []
                if isinstance(r, dict) and r.get("scenario") == "fault_storm"
            ]
            for i, row in enumerate(storms):
                where = f"ablate_scheduler.fault_storm[{i}]"
                if not self.require_keys(
                    row,
                    ["seed", "jobs", "completed", "completion_rate", "secs", "recovery_ms", "timed_out"],
                    where,
                ):
                    continue
                for k in ("seed", "jobs", "completed", "completion_rate", "secs", "recovery_ms"):
                    if not is_num_or_null(row[k]):
                        self.err(where, f"{k!r} should be a number or null, got {row[k]!r}")
                if not (row["timed_out"] is None or isinstance(row["timed_out"], bool)):
                    self.err(where, f"'timed_out' should be a bool or null, got {row['timed_out']!r}")
            # PR 10: the mixed_tenant scenario reports per-class queue
            # waits under the v11 QoS policy vs the v10 FIFO discipline.
            mixed = [
                r
                for r in doc["ablate_scheduler"] or []
                if isinstance(r, dict) and r.get("scenario") == "mixed_tenant"
            ]
            by_mode = {}
            for i, row in enumerate(mixed):
                where = f"ablate_scheduler.mixed_tenant[{i}]"
                if not self.require_keys(
                    row,
                    [
                        "mode",
                        "backfill",
                        "preemption",
                        "interactive_p50_ms",
                        "interactive_p99_ms",
                        "batch_p50_ms",
                        "batch_p99_ms",
                        "batch_jobs_per_s",
                        "interactive_jobs_per_s",
                    ],
                    where,
                ):
                    continue
                for k in (
                    "interactive_p50_ms",
                    "interactive_p99_ms",
                    "batch_p50_ms",
                    "batch_p99_ms",
                    "batch_jobs_per_s",
                    "interactive_jobs_per_s",
                ):
                    if not is_num_or_null(row[k]):
                        self.err(where, f"{k!r} should be a number or null, got {row[k]!r}")
                for k in ("backfill", "preemption"):
                    if not (row[k] is None or isinstance(row[k], bool)):
                        self.err(where, f"{k!r} should be a bool or null, got {row[k]!r}")
                if row["mode"] in ("qos", "fifo"):
                    by_mode[row["mode"]] = row
                elif row["mode"] is not None:
                    self.err(where, f"'mode' should be 'qos'/'fifo' or null, got {row['mode']!r}")
            # The acceptance claim the snapshot carries (null-safe: a
            # schema seed skips both checks): the v11 policy improves the
            # interactive p99 without giving up batch throughput.
            if "qos" in by_mode and "fifo" in by_mode:
                q, f = by_mode["qos"], by_mode["fifo"]
                if (
                    isinstance(q.get("interactive_p99_ms"), NUM)
                    and isinstance(f.get("interactive_p99_ms"), NUM)
                    and q["interactive_p99_ms"] > f["interactive_p99_ms"]
                ):
                    self.err(
                        "ablate_scheduler.mixed_tenant",
                        "qos interactive p99 should not exceed fifo: "
                        f"{q['interactive_p99_ms']} vs {f['interactive_p99_ms']}",
                    )
                if (
                    isinstance(q.get("batch_jobs_per_s"), NUM)
                    and isinstance(f.get("batch_jobs_per_s"), NUM)
                    and q["batch_jobs_per_s"] < 0.9 * f["batch_jobs_per_s"]
                ):
                    self.err(
                        "ablate_scheduler.mixed_tenant",
                        "qos batch throughput fell >10% below fifo: "
                        f"{q['batch_jobs_per_s']} vs {f['batch_jobs_per_s']}",
                    )
        # PR 7: the table2/table3 transfer benches emit transfer_grid
        # rows plus the transport x compression sweep.
        for section in ("table2_transfer_tall", "table3_transfer_wide"):
            if section not in doc:
                continue
            self.rows(doc, section, ["scenario"], ["secs", "mb_per_s", "spark", "alch"])
            sweeps = [
                r
                for r in doc[section] or []
                if isinstance(r, dict) and r.get("scenario") == "transport_sweep"
            ]
            if not sweeps:
                self.err(section, "expected at least one transport_sweep row")
            for i, row in enumerate(sweeps):
                self.require_keys(
                    row,
                    ["table", "transport", "compression", "secs", "mb_per_s"],
                    f"{section}.transport_sweep[{i}]",
                )
        # PR 9: ablate_gemm_backend emits the summa2d process-grid sweep.
        if "ablate_gemm_backend" in doc:
            self.rows(
                doc,
                "ablate_gemm_backend",
                ["scenario"],
                ["p_r", "p_c", "ranks", "n", "secs", "per_rank_bcast_bytes", "peak_tmp_doubles"],
            )
            sweeps = [
                r
                for r in doc["ablate_gemm_backend"] or []
                if isinstance(r, dict) and r.get("scenario") == "grid_sweep"
            ]
            if not sweeps:
                self.err("ablate_gemm_backend", "expected at least one grid_sweep row")
            for i, row in enumerate(sweeps):
                where = f"ablate_gemm_backend.grid_sweep[{i}]"
                self.require_keys(
                    row,
                    [
                        "backend",
                        "grid",
                        "p_r",
                        "p_c",
                        "ranks",
                        "n",
                        "secs",
                        "per_rank_bcast_bytes",
                        "peak_tmp_doubles",
                    ],
                    where,
                )
            # The acceptance claim the snapshot carries: on a square
            # problem the auto/square grid moves fewer bytes per rank
            # than the 1xp degeneration at the same (n, ranks).
            by_shape = {}
            for row in sweeps:
                if not isinstance(row, dict) or not is_num_or_null(row.get("per_rank_bcast_bytes")):
                    continue
                if row.get("per_rank_bcast_bytes") is None:
                    continue
                key = (row.get("n"), row.get("ranks"))
                by_shape.setdefault(key, {})[(row.get("p_r"), row.get("p_c"))] = row[
                    "per_rank_bcast_bytes"
                ]
            for key, grids in by_shape.items():
                flat = [v for (pr, pc), v in grids.items() if pr == 1 or pc == 1]
                square = [v for (pr, pc), v in grids.items() if pr != 1 and pc != 1]
                if flat and square and min(square) >= min(flat):
                    self.err(
                        f"ablate_gemm_backend.grid_sweep{key}",
                        f"square grid should move fewer bytes/rank than 1xp: {grids}",
                    )
        if "telemetry" in doc:
            self.telemetry(doc)
        return self.errors


def main(argv):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    paths = argv or sorted(glob.glob(os.path.join(root, "BENCH_PR*.json")))
    if not paths:
        print("validate_bench_json: no BENCH_PR*.json found", file=sys.stderr)
        return 1
    failed = False
    for path in paths:
        errors = Checker(path).run()
        if errors:
            failed = True
            for e in errors:
                print(f"FAIL {e}", file=sys.stderr)
        else:
            print(f"ok {path}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
