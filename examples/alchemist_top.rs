//! `top` for an Alchemist server — a live view of the v8 telemetry
//! plane. Starts an in-process server, pushes a workload through it, and
//! renders what `FetchTelemetry` returns while the jobs run: scheduler
//! occupancy, per-rank counters, and the per-job send/compute/receive
//! breakdown the paper reports (Table 1 / Fig 3).
//!
//! ```text
//! cargo run --release --example alchemist_top -- \
//!     [--workers N] [--jobs N] [--headless] \
//!     [--snapshot-json PATH] [--chrome PATH]
//! ```
//!
//! `--headless` skips the live ticks (CI / bench_snapshot.sh use this);
//! `--snapshot-json` / `--chrome` write the final merged report as a
//! JSON snapshot / a chrome://tracing (Perfetto-loadable) span export.

use std::time::Duration;

use alchemist::ali::params::ParamsBuilder;
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::LayoutKind;
use alchemist::server::start_server;
use alchemist::telemetry::{TelemetryReport, AMBIENT_TRACE};
use alchemist::workload::random_matrix;

struct Args {
    workers: u32,
    jobs: usize,
    headless: bool,
    snapshot_json: Option<String>,
    chrome: Option<String>,
}

fn parse_args() -> Args {
    let mut args =
        Args { workers: 2, jobs: 3, headless: false, snapshot_json: None, chrome: None };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let mut need = |name: &str| it.next().unwrap_or_else(|| panic!("{name} needs a value"));
        match a.as_str() {
            "--workers" => args.workers = need("--workers").parse().expect("--workers N"),
            "--jobs" => args.jobs = need("--jobs").parse().expect("--jobs N"),
            "--headless" => args.headless = true,
            "--snapshot-json" => args.snapshot_json = Some(need("--snapshot-json")),
            "--chrome" => args.chrome = Some(need("--chrome")),
            other => panic!("unknown flag {other:?} (see the header comment)"),
        }
    }
    args
}

/// One status frame rendered from a merged report.
fn render(report: &TelemetryReport) {
    let c = |k: &str| report.registry.counters.get(k).copied().unwrap_or(0);
    let g = |k: &str| report.registry.gauges.get(k).copied().unwrap_or(0);
    println!(
        "  sched: {} submitted / {} done / {} failed | inflight {} | queue {}",
        c("sched.jobs_submitted"),
        c("sched.jobs_done"),
        c("sched.jobs_failed"),
        g("sched.jobs_inflight"),
        g("sched.queue_depth"),
    );
    println!(
        "  qos: queue int/batch/be {}/{}/{} | {} preemption(s), {} backfill(s)",
        g("sched.queue_depth_interactive"),
        g("sched.queue_depth_batch"),
        g("sched.queue_depth_best_effort"),
        c("sched.preemptions"),
        c("sched.backfills"),
    );
    println!(
        "  transfer: {} rows out ({} B), {} rows in ({} B)",
        c("transfer.rows_sent"),
        c("transfer.bytes_sent"),
        c("transfer.rows_recv"),
        c("transfer.bytes_recv"),
    );
    println!(
        "  compute: backend code {} | grid {}x{} | gemms ring/allgather/summa2d {}/{}/{}",
        g("compute.backend"),
        g("compute.grid_r"),
        g("compute.grid_c"),
        c("compute.ring_gemms"),
        c("compute.allgather_gemms"),
        c("compute.summa_gemms"),
    );
    let mut rank = 0u32;
    loop {
        let key = format!("w{rank}.jobs_run");
        if !report.registry.counters.contains_key(&key) {
            break;
        }
        println!(
            "  w{rank}: {} routines run, {} slab frames ({} B) received",
            c(&key),
            c(&format!("w{rank}.slab_frames")),
            c(&format!("w{rank}.slab_bytes")),
        );
        rank += 1;
    }
    let jobs: std::collections::BTreeSet<u64> = report
        .spans
        .iter()
        .map(|s| s.trace_id)
        .filter(|&t| t != AMBIENT_TRACE)
        .collect();
    println!("  spans: {} recorded across {} job trace(s)", report.spans.len(), jobs.len());
}

fn main() -> alchemist::Result<()> {
    alchemist::logging::init_from_env();
    let args = parse_args();

    let mut cfg = Config::default();
    cfg.server.workers = args.workers;
    cfg.server.gemm_backend = "native".into();
    let server = start_server(&cfg)?;
    let mut ac = AlchemistContext::connect(&server.driver_addr, "alchemist_top")?;
    ac.request_workers(args.workers)?;
    wrappers::register_elemlib(&ac)?;

    let a = DenseMatrix::from_vec(240, 24, random_matrix(1, 240, 24))?;
    let al = ac.send_dense(&a, LayoutKind::RowBlock)?;

    // Submit the whole batch up front, then watch it drain.
    let handles: Vec<_> = (0..args.jobs)
        .map(|i| {
            if i % 2 == 0 {
                ac.run_async(
                    "elemlib",
                    "gramian",
                    ParamsBuilder::new().matrix("A", al.handle()).build(),
                )
            } else {
                ac.run_async(
                    "elemlib",
                    "truncated_svd",
                    ParamsBuilder::new().matrix("A", al.handle()).i64("k", 4).build(),
                )
            }
        })
        .collect::<alchemist::Result<_>>()?;
    println!("{} job(s) submitted on {} worker(s)", handles.len(), args.workers);

    // Live ticks while the queue drains (the pull is cheap: one control
    // round trip + one bounded data-plane exchange per worker).
    loop {
        let done = handles
            .iter()
            .map(|h| Ok(h.is_finished()? as usize))
            .sum::<alchemist::Result<usize>>()?;
        if !args.headless {
            let report = ac.fetch_telemetry(None)?;
            println!("-- alchemist_top: {done}/{} jobs done --", handles.len());
            render(&report);
        }
        if done == handles.len() {
            break;
        }
        std::thread::sleep(Duration::from_millis(if args.headless { 5 } else { 100 }));
    }

    // Per-job phase rows (the paper's decomposition, from the trace).
    println!("\nper-job breakdown (send/receive are context-cumulative):");
    println!(
        "  {:>6} {:>12} {:>10} {:>10} {:>10} {:>10}",
        "job", "queue_wait_s", "compute_s", "total_s", "send_s", "receive_s"
    );
    for h in &handles {
        let bd = h.phase_breakdown()?;
        println!(
            "  {:>6} {:>12.6} {:>10.6} {:>10.6} {:>10.6} {:>10.6}",
            h.job_id, bd.queue_wait_s, bd.compute_s, bd.total_s, bd.send_s, bd.receive_s
        );
    }
    for h in handles {
        h.wait()?;
    }

    // Final merged snapshot + optional exports.
    let report = ac.fetch_telemetry(None)?;
    println!("\nfinal snapshot:");
    render(&report);
    if let Some(path) = &args.snapshot_json {
        std::fs::write(path, report.to_json())?;
        println!("wrote JSON snapshot to {path}");
    }
    if let Some(path) = &args.chrome {
        std::fs::write(path, report.chrome_trace())?;
        println!("wrote chrome://tracing export to {path} (load in Perfetto)");
    }

    ac.stop()?;
    server.shutdown();
    println!("\nalchemist_top OK");
    Ok(())
}
