//! A compact §4.3-style transfer sweep: ship the same number of bytes as
//! a tall-skinny vs a short-wide matrix, over a small grid of (client
//! partitions × Alchemist workers), and print the Table-2/3-shaped grid.
//! (The full grids are `cargo bench --bench table2_transfer_tall` /
//! `table3_transfer_wide`.)
//!
//! `cargo run --release --example transfer_sweep`

use alchemist::bench_support::harness::Table;
use alchemist::client::AlchemistContext;
use alchemist::config::Config;
use alchemist::metrics::Timer;
use alchemist::server::start_server;
use alchemist::sparklet::{IndexedRowMatrix, SparkletContext};

fn run_transfer(
    spark_nodes: u32,
    alchemist_nodes: u32,
    rows: u64,
    cols: u64,
) -> alchemist::Result<f64> {
    let mut cfg = Config::default();
    cfg.server.workers = alchemist_nodes;
    cfg.server.gemm_backend = "native".into(); // no compute in this sweep
    cfg.sparklet.executors = spark_nodes;
    cfg.sparklet.task_overhead_us = 0;
    cfg.sparklet.executor_mem_mb = 4096;

    let server = start_server(&cfg)?;
    let sc = SparkletContext::new(&cfg.sparklet)?;
    let a = IndexedRowMatrix::random(&sc, 99, rows, cols, spark_nodes, None)?;

    let mut ac = AlchemistContext::connect(&server.driver_addr, "transfer_sweep")?;
    // paper behaviour: one row per message (what creates the tall-vs-wide
    // gap; see `cargo bench --bench ablate_framing` for the batched fix)
    ac.batch_rows = 1;
    ac.request_workers(alchemist_nodes)?;
    let t = Timer::start();
    let al_a = a.to_alchemist(&sc, &ac)?;
    let secs = t.elapsed_secs();
    assert_eq!(al_a.rows(), rows);
    ac.stop()?;
    sc.shutdown();
    server.shutdown();
    Ok(secs)
}

fn main() -> alchemist::Result<()> {
    alchemist::logging::init_from_env();
    // ~26 MB each, 64x row-count difference
    let tall = (32_768u64, 100u64);
    let wide = (512u64, 6_400u64);
    let grid = [2u32, 4, 8];

    for (label, (rows, cols)) in [("tall-skinny", tall), ("short-wide", wide)] {
        println!(
            "\n{label}: {rows} x {cols} (~{:.0} MB)",
            (rows * cols * 8) as f64 / 1e6
        );
        let mut table = Table::new(
            &std::iter::once("#spark".to_string())
                .chain(grid.iter().map(|w| format!("{w} alch")))
                .collect::<Vec<_>>()
                .iter()
                .map(|s| s.as_str())
                .collect::<Vec<_>>(),
        );
        for &s in &grid {
            let mut cells = vec![s.to_string()];
            for &w in &grid {
                let secs = run_transfer(s, w, rows, cols)?;
                cells.push(format!("{secs:.2}s"));
            }
            table.row(cells);
        }
        table.print();
    }
    println!("\n(expect: tall-skinny slower at equal bytes — §4.3's per-row message effect)");
    Ok(())
}
