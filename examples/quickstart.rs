//! Quickstart — mirrors the paper's §3.3 sample application:
//!
//! ```scala
//! val ac = new Alchemist.AlchemistContext(sc, numWorkers)
//! ac.registerLibrary(ALIlibAName, ALIlibALocation)
//! val alA = AlMatrix(A)
//! val output = ac.run(ALIlibAName, "condest", alA)
//! ac.stop()
//! ```
//!
//! Run with `cargo run --release --example quickstart`.

use alchemist::ali::params::ParamsBuilder;
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::LayoutKind;
use alchemist::server::start_server;
use alchemist::workload::random_matrix;

fn main() -> alchemist::Result<()> {
    alchemist::logging::init_from_env();

    // Start an Alchemist server (in production this is `alchemist serve`
    // on dedicated nodes; here we spin it up in-process).
    let mut cfg = Config::default();
    cfg.server.workers = 4;
    let server = start_server(&cfg)?;
    println!("alchemist driver at {}", server.driver_addr);

    // ---- the §3.3 client flow ----
    let mut ac = AlchemistContext::connect(&server.driver_addr, "quickstart")?;
    ac.request_workers(4)?;
    ac.register_library("elemlib", "builtin:elemlib")?;

    // A is an "IndexedRowMatrix in the application"; here a local matrix.
    let a = DenseMatrix::from_vec(512, 64, random_matrix(7, 512, 64))?;
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock)?; // val alA = AlMatrix(A)

    // val output = ac.run(libA, "condest", alA)
    let (outputs, _) = ac.run(
        "elemlib",
        "condest",
        ParamsBuilder::new().matrix("A", al_a.handle()).build(),
    )?;
    println!("condest(A) = {:.4}", outputs[0].1.as_f64()?);

    // Library-wrapper sugar (§3.4): same call, MLlib-shaped.
    let cond = wrappers::cond_est(&ac, &al_a)?;
    println!("CondEst(alA) = {cond:.4}");

    // Chain a GEMM without any data round trip: B = Aᵀ? (use A with
    // itself via a scaled copy), then fetch the result explicitly.
    let b = DenseMatrix::from_vec(64, 32, random_matrix(8, 64, 32))?;
    let al_b = ac.send_dense(&b, LayoutKind::RowBlock)?;
    let al_c = wrappers::gemm(&ac, &al_a, &al_b)?;
    let c = ac.fetch_dense(&al_c)?; // explicit AlMatrix -> local
    println!(
        "C = A*B is {}x{}, ‖C‖_F = {:.4}",
        c.rows(),
        c.cols(),
        c.frobenius_norm()
    );

    // verify against local compute
    let want = alchemist::linalg::gemm::gemm(&a, &b)?;
    assert!(c.max_abs_diff(&want)? < 1e-9, "Alchemist GEMM disagrees with local");
    println!("verified against local GEMM ✓");

    println!(
        "phase times: send {:.3}s, compute {:.3}s, receive {:.3}s",
        ac.phases.get_secs("send"),
        ac.phases.get_secs("compute"),
        ac.phases.get_secs("receive"),
    );

    ac.stop()?; // ac.stop()
    server.shutdown();
    Ok(())
}
