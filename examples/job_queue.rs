//! Job-queue / admission-control demo — the scenario the `sched`
//! subsystem exists for: **more concurrent client applications than free
//! workers**. Six tenants share a three-worker pool; with
//! `request_workers_wait` nobody sees the paper's hard
//! `insufficient workers` failure — late arrivals park in the driver's
//! FIFO admission queue and are granted as earlier tenants finish. The
//! second half pipelines several routines through one session with
//! `run_async`, overlapping submission with execution.
//!
//! `cargo run --release --example job_queue`

use std::time::Duration;

use alchemist::ali::params::ParamsBuilder;
use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::LayoutKind;
use alchemist::server::start_server;
use alchemist::workload::random_matrix;

fn main() -> alchemist::Result<()> {
    alchemist::logging::init_from_env();
    let mut cfg = Config::default();
    cfg.server.workers = 3;
    cfg.server.gemm_backend = "native".into();
    let server = start_server(&cfg)?;
    let addr = server.driver_addr.clone();

    // --- Part 1: oversubscription with queued admission -----------------
    const TENANTS: u64 = 6;
    println!("pool: 3 workers, tenants: {TENANTS} (each wants 1-2 workers)");
    let mut apps = Vec::new();
    for app in 0..TENANTS {
        let addr = addr.clone();
        apps.push(std::thread::spawn(move || -> alchemist::Result<(u64, usize, f64)> {
            let mut ac = AlchemistContext::connect(&addr, &format!("tenant-{app}"))?;
            // Tenants alternate between 1- and 2-worker requests; all
            // park in FIFO order when the pool is busy.
            let want = 1 + (app % 2) as u32;
            ac.request_workers_wait(want, 30_000)?;
            let got = ac.workers().len();
            wrappers::register_elemlib(&ac)?;
            let a = DenseMatrix::from_vec(120, 8, random_matrix(app, 120, 8))?;
            let al = ac.send_dense(&a, LayoutKind::RowBlock)?;
            let norm = wrappers::fro_norm(&ac, &al)?;
            assert!((norm - a.frobenius_norm()).abs() < 1e-9);
            ac.stop()?;
            Ok((app, got, norm))
        }));
    }

    // Watch the admission queue from an observer session.
    let obs = AlchemistContext::connect(&addr, "observer")?;
    let mut max_queued = 0;
    for _ in 0..100 {
        let st = obs.scheduler_status()?;
        max_queued = max_queued.max(st.queued_sessions);
        if st.queued_sessions == 0 && st.sessions <= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }

    for app in apps {
        let (id, got, norm) = app.join().expect("tenant panicked")?;
        println!("tenant-{id}: granted {got} worker(s), ‖A‖_F = {norm:.3} ✓");
    }
    println!("peak admission-queue depth observed: {max_queued}");
    println!("all {TENANTS} tenants completed with zero admission failures ✓\n");

    // --- Part 2: async pipelining inside one session ---------------------
    let mut ac = AlchemistContext::connect(&addr, "pipeliner")?;
    ac.request_workers_wait(3, 30_000)?;
    wrappers::register_elemlib(&ac)?;
    let a = DenseMatrix::from_vec(300, 24, random_matrix(42, 300, 24))?;
    let al = ac.send_dense(&a, LayoutKind::RowBlock)?;

    // Submit a batch of routines before collecting any result: the
    // control connection never blocks on execution.
    let jobs: Vec<_> = (0..4)
        .map(|_| {
            ac.run_async(
                "elemlib",
                "gramian",
                ParamsBuilder::new().matrix("A", al.handle()).build(),
            )
        })
        .collect::<alchemist::Result<_>>()?;
    println!("submitted {} jobs before waiting on any of them", jobs.len());
    let inflight = obs.scheduler_status()?.jobs_inflight;
    println!("scheduler reports {inflight} job(s) in flight");
    for h in jobs {
        let id = h.job_id;
        let (_, mats) = h.wait()?;
        println!("job {id}: done ({} output matrix)", mats.len());
    }
    ac.stop()?;
    obs.stop()?;
    server.shutdown();
    println!("\njob_queue OK");
    Ok(())
}
