//! Adding a new MPI-based library (paper §3.5) + a library wrapper
//! (§3.4): implements a custom `Library` ("statlib" — column means and a
//! row-count routine), installs it through the factory registry (the
//! `dlopen` substitute), registers it from the client by (name, path),
//! and wraps it in MLlib-shaped sugar.
//!
//! `cargo run --release --example library_wrapper`

use std::sync::Arc;

use alchemist::ali::params::{self, ParamsBuilder};
use alchemist::ali::registry::install_factory;
use alchemist::ali::{Library, RoutineCtx, RoutineOutput};
use alchemist::client::{AlMatrix, AlchemistContext};
use alchemist::comm::collectives;
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::{LayoutKind, ParamValue, Params};
use alchemist::server::start_server;
use alchemist::workload::random_matrix;
use alchemist::{Error, Result};

/// The custom "MPI library": distributed column statistics.
struct StatLib;

impl Library for StatLib {
    fn name(&self) -> &str {
        "statlib"
    }

    fn routines(&self) -> Vec<&'static str> {
        vec!["col_means", "count_rows"]
    }

    fn run(&self, routine: &str, p: &Params, ctx: &mut RoutineCtx<'_>) -> Result<RoutineOutput> {
        match routine {
            // SPMD: local partial sums + one all-reduce — exactly how an
            // MPI statistics kernel would be written.
            "col_means" => {
                let h = params::get_matrix(p, "A")?;
                let a = ctx.store.get(h)?;
                let n = a.meta.cols as usize;
                let mut sums = vec![0.0; n];
                for (_, row) in a.iter_rows() {
                    alchemist::linalg::blas1::axpy(1.0, row, &mut sums);
                }
                collectives::allreduce_sum(ctx.mesh, &mut sums, collectives::AllReduceAlgo::Ring)?;
                let m = a.meta.rows as f64;
                let means: Vec<f64> = sums.iter().map(|s| s / m).collect();
                // return as a k x 1 distributed matrix so the client can
                // fetch it like any other AlMatrix
                let handle = ctx.output_handle(0)?;
                let meta = alchemist::protocol::MatrixMeta {
                    handle,
                    rows: n as u64,
                    cols: 1,
                    layout: alchemist::protocol::LayoutDesc {
                        kind: LayoutKind::RowBlock,
                        owners: ctx.owners.clone(),
                    },
                };
                let rank = ctx.mesh.rank() as u32;
                let mut panel = alchemist::elemental::LocalPanel::alloc(meta.clone(), rank)?;
                let layout = panel.layout();
                for r in layout.rows_of_slot(rank).collect::<Vec<_>>() {
                    panel.set_row(r, &[means[r as usize]])?;
                }
                ctx.store.insert(panel)?;
                Ok(RoutineOutput { outputs: vec![], new_matrices: vec![meta] })
            }
            "count_rows" => {
                let h = params::get_matrix(p, "A")?;
                let a = ctx.store.get(h)?;
                let mut c = vec![a.local_rows() as f64];
                collectives::allreduce_sum(ctx.mesh, &mut c, collectives::AllReduceAlgo::Ring)?;
                Ok(RoutineOutput {
                    outputs: vec![("rows".into(), ParamValue::I64(c[0] as i64))],
                    new_matrices: vec![],
                })
            }
            other => Err(Error::Ali(format!("statlib has no routine {other:?}"))),
        }
    }
}

/// §3.4-style wrapper: `ColMeans(alA)` instead of raw run() plumbing.
fn col_means(ac: &AlchemistContext, a: &AlMatrix) -> Result<Vec<f64>> {
    let (_, mats) = ac.run(
        "statlib",
        "col_means",
        ParamsBuilder::new().matrix("A", a.handle()).build(),
    )?;
    let m = mats.into_iter().next().ok_or_else(|| Error::Ali("no output".into()))?;
    let dense = ac.fetch_dense(&m)?;
    Ok((0..dense.rows()).map(|i| dense.get(i, 0)).collect())
}

fn main() -> Result<()> {
    alchemist::logging::init_from_env();

    // "Compile the ALI and drop it next to the server" — the factory
    // install is our dlopen substitute (DESIGN.md).
    install_factory("file://libstatlib.so", || Arc::new(StatLib));

    let mut cfg = Config::default();
    cfg.server.workers = 3;
    let server = start_server(&cfg)?;
    let mut ac = AlchemistContext::connect(&server.driver_addr, "library_wrapper")?;
    ac.request_workers(3)?;

    // Client registers the new library by (name, path), §3.3-style.
    ac.register_library("statlib", "file://libstatlib.so")?;

    let a = DenseMatrix::from_vec(1000, 8, random_matrix(3, 1000, 8))?;
    let al_a = ac.send_dense(&a, LayoutKind::RowBlock)?;

    let means = col_means(&ac, &al_a)?;
    println!("col_means = {means:?}");

    // verify against local compute
    for j in 0..8 {
        let want: f64 = (0..1000).map(|i| a.get(i, j)).sum::<f64>() / 1000.0;
        assert!((means[j] - want).abs() < 1e-12, "column {j}");
    }
    println!("column means verified ✓");

    let (out, _) = ac.run(
        "statlib",
        "count_rows",
        ParamsBuilder::new().matrix("A", al_a.handle()).build(),
    )?;
    assert_eq!(out[0].1.as_i64()?, 1000);
    println!("count_rows = {} ✓", out[0].1.as_i64()?);

    ac.stop()?;
    server.shutdown();
    println!("library_wrapper OK");
    Ok(())
}
