//! Domain example: distributed linear regression — the data-science
//! workload the paper's introduction motivates (large tall-skinny design
//! matrices, MPI-grade solvers behind a Spark-style front end).
//!
//! The client generates a planted linear model inside sparklet, ships
//! (A, y) to Alchemist executor-parallel, solves the normal equations via
//! ElemLib's `lstsq` (distributed Gram all-reduce + local Cholesky), and
//! verifies the recovered coefficients and residual.
//!
//! `cargo run --release --example linear_regression`

use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::protocol::LayoutKind;
use alchemist::server::start_server;
use alchemist::sparklet::SparkletContext;
use alchemist::sparklet::IndexedRowMatrix;
use alchemist::workload::{random_row, Rng};

fn main() -> alchemist::Result<()> {
    alchemist::logging::init_from_env();
    let mut cfg = Config::default();
    cfg.server.workers = 6;
    cfg.sparklet.executors = 3;
    cfg.sparklet.executor_mem_mb = 2048;

    let (m, n, seed) = (50_000u64, 24usize, 77u64);
    // planted coefficients + noise level
    let x_true: Vec<f64> = (0..n).map(|j| ((j as f64) * 0.7).cos() * 3.0).collect();
    let noise = 0.01;

    println!("workload: {m} x {n} design matrix, planted coefficients, noise σ={noise}");
    let server = start_server(&cfg)?;
    let sc = SparkletContext::new(&cfg.sparklet)?;

    // Design matrix generated in sparklet, shipped executor-parallel.
    let a = IndexedRowMatrix::random(&sc, seed, m, n as u64, 6, None)?;
    let mut ac = AlchemistContext::connect(&server.driver_addr, "linreg")?;
    ac.request_workers(cfg.server.workers)?;
    wrappers::register_elemlib(&ac)?;
    let al_a = a.to_alchemist(&sc, &ac)?;

    // y = A x_true + noise, derived row-by-row from the same seeded
    // generator (so no full matrix ever materializes on the driver).
    let al_y = ac.create_matrix(m, 1, LayoutKind::RowBlock)?;
    let x_c = x_true.clone();
    ac.put_rows(
        &al_y,
        (0..m).map(move |i| {
            let row = random_row(seed, i, n);
            let mut rng = Rng::new(seed ^ (i + 1));
            let y: f64 = row.iter().zip(&x_c).map(|(a, b)| a * b).sum::<f64>()
                + noise * rng.next_gaussian();
            (i, vec![y])
        }),
    )?;
    ac.finish_put(&al_y)?;

    // Distributed least squares.
    let t = alchemist::metrics::Timer::start();
    let (al_x, residual) = wrappers::lstsq(&ac, &al_a, &al_y, 0.0)?;
    let solve_secs = t.elapsed_secs();
    let x = ac.fetch_dense(&al_x)?;

    println!("solved in {solve_secs:.3}s; residual norm {residual:.4}");
    let mut max_err: f64 = 0.0;
    for j in 0..n {
        max_err = max_err.max((x.get(j, 0) - x_true[j]).abs());
    }
    println!("max |x - x_true| = {max_err:.2e} (noise floor ~{:.1e})", noise / (m as f64).sqrt());
    assert!(max_err < 0.01, "coefficients off: {max_err}");

    // residual should be ~ noise * sqrt(m)
    let expected_res = noise * (m as f64).sqrt();
    assert!(
        residual < 3.0 * expected_res,
        "residual {residual} vs expected ~{expected_res}"
    );
    println!("coefficients and residual verified ✓");

    // bonus: column stats of the design matrix (uniform[-1,1]: mean~0, std~0.577)
    let stats = wrappers::col_stats(&ac, &al_a)?;
    let s = ac.fetch_dense(&stats)?;
    assert!(s.get(0, 0).abs() < 0.02, "mean {}", s.get(0, 0));
    assert!((s.get(0, 1) - (1.0f64 / 3.0).sqrt()).abs() < 0.02, "std {}", s.get(0, 1));
    println!("column statistics verified ✓ (mean≈0, std≈1/√3)");

    ac.stop()?;
    sc.shutdown();
    server.shutdown();
    println!("linear_regression OK");
    Ok(())
}
