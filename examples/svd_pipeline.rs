//! End-to-end validation driver (DESIGN.md §6) — the paper's §4.2
//! experiment as a single runnable pipeline:
//!
//! 1. generate a tall-skinny dense matrix *inside sparklet* (as the paper
//!    generates data inside Spark),
//! 2. rank-20 truncated SVD the **Spark way** (sparklet `compute_svd`:
//!    one scheduled aggregation stage per Lanczos iteration),
//! 3. rank-20 truncated SVD the **Spark+Alchemist way** (executors push
//!    rows to Alchemist workers over sockets; ElemLib runs the
//!    ARPACK-substitute over the session mesh with PJRT/Pallas local
//!    compute; results fetched back),
//! 4. verify both against a local reference to 1e-6, and report the
//!    paper's headline metrics: speedup and transfer-overhead fraction.
//!
//! `cargo run --release --example svd_pipeline [-- --set k=v ...]`

use alchemist::client::{wrappers, AlchemistContext};
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::metrics::Timer;
use alchemist::server::start_server;
use alchemist::sparklet::{IndexedRowMatrix, SparkletContext};
use alchemist::workload::spectral_row;

fn main() -> alchemist::Result<()> {
    alchemist::logging::init_from_env();
    let overrides: Vec<String> = std::env::args()
        .skip(1)
        .collect::<Vec<_>>()
        .windows(2)
        .filter(|w| w[0] == "--set")
        .map(|w| w[1].clone())
        .collect();
    let mut cfg = Config::default();
    cfg.server.workers = 8;
    cfg.sparklet.executors = 4;
    cfg.sparklet.default_parallelism = 8;
    cfg.sparklet.executor_mem_mb = 2048;
    cfg.apply_overrides(&overrides)?;

    // Scaled §4.2 workload: tall-skinny with decaying spectrum, k=20.
    let (m, n, k, seed, decay) = (40_000u64, 256u64, 20usize, 42u64, 0.97f64);
    println!("workload: {m} x {n} dense (decaying spectrum), rank-{k} truncated SVD");
    println!(
        "spark side: {} executors; alchemist side: {} workers ({} backend)\n",
        cfg.sparklet.executors, cfg.server.workers, cfg.server.gemm_backend
    );

    let sc = SparkletContext::new(&cfg.sparklet)?;
    let a = IndexedRowMatrix::random(&sc, seed, m, n, cfg.sparklet.default_parallelism, Some(decay))?;

    // ---- Spark-only path ----
    let t = Timer::start();
    let spark_svd = a.compute_svd(&sc, k, false, 1e-10)?;
    let spark_secs = t.elapsed_secs();
    println!(
        "sparklet computeSVD:      {spark_secs:>8.2}s  ({} stages of {} tasks)",
        spark_svd.matvecs,
        cfg.sparklet.default_parallelism
    );

    // ---- Spark+Alchemist path ----
    let server = start_server(&cfg)?;
    let mut ac = AlchemistContext::connect(&server.driver_addr, "svd_pipeline")?;
    ac.request_workers(cfg.server.workers)?;
    wrappers::register_elemlib(&ac)?;

    let t = Timer::start();
    let al_a = a.to_alchemist(&sc, &ac)?; // executors push rows
    let svd = wrappers::truncated_svd(&ac, &al_a, k)?;
    let s_mat = ac.fetch_dense(&svd.s)?;
    let _v = ac.fetch_dense(&svd.v)?;
    let alchemist_secs = t.elapsed_secs();
    let send = ac.phases.get_secs("send");
    let recv = ac.phases.get_secs("receive");
    let compute = ac.phases.get_secs("compute");
    println!(
        "spark+alchemist tsvd:     {alchemist_secs:>8.2}s  (send {send:.2}s | compute {compute:.2}s | receive {recv:.2}s)"
    );

    // ---- verification against a local reference ----
    let mut data = Vec::with_capacity((m * n) as usize);
    for i in 0..m {
        data.extend_from_slice(&spectral_row(seed, i, n as usize, decay));
    }
    let local = DenseMatrix::from_vec(m as usize, n as usize, data)?;
    let reference = alchemist::arpack::truncated_svd_local(
        &local,
        k,
        &alchemist::arpack::LanczosOptions::default(),
    )?;
    let mut max_err: f64 = 0.0;
    for i in 0..k {
        let al = s_mat.get(i, 0);
        let sp = spark_svd.singular_values[i];
        let rf = reference.singular_values[i];
        max_err = max_err.max((al - rf).abs() / rf).max((sp - rf).abs() / rf);
    }
    println!("\nmax relative σ error vs local reference: {max_err:.2e}");
    assert!(max_err < 1e-6, "singular values disagree");

    // ---- headline metrics ----
    let speedup = spark_secs / alchemist_secs;
    let overhead = (send + recv) / alchemist_secs;
    println!("speedup (spark / spark+alchemist):  {speedup:.1}x");
    println!("transfer overhead fraction:         {:.0}%  (paper reports ~20%)", overhead * 100.0);
    println!("gram matvecs: alchemist {}, sparklet {}", svd.matvecs, spark_svd.matvecs);
    println!("\nsvd_pipeline OK ✓  (record in EXPERIMENTS.md)");

    ac.stop()?;
    server.shutdown();
    sc.shutdown();
    Ok(())
}
