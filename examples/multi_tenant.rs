//! Multi-tenant scenario — the paper's Fig 2: one Alchemist server, two
//! concurrent client applications on **disjoint worker groups** (group I:
//! 4 workers, group II: 3 workers), each registering only the libraries
//! it needs, running concurrently without interference.
//!
//! `cargo run --release --example multi_tenant`

use alchemist::client::AlchemistContext;
use alchemist::config::Config;
use alchemist::linalg::DenseMatrix;
use alchemist::protocol::LayoutKind;
use alchemist::server::start_server;
use alchemist::workload::random_matrix;

fn main() -> alchemist::Result<()> {
    alchemist::logging::init_from_env();
    let mut cfg = Config::default();
    cfg.server.workers = 10; // 1 driver + 10 workers; Fig 2 uses 9 + driver
    let server = start_server(&cfg)?;
    let addr = server.driver_addr.clone();

    // Application 1: three "executors" worth of work, 4 Alchemist workers,
    // libraries A and C (here: elemlib twice under different names).
    let addr1 = addr.clone();
    let app1 = std::thread::spawn(move || -> alchemist::Result<(f64, Vec<u32>)> {
        let mut ac = AlchemistContext::connect(&addr1, "application-1")?;
        ac.request_workers(4)?;
        let ids = ac.workers().iter().map(|w| w.id).collect::<Vec<_>>();
        ac.register_library("libA", "builtin:elemlib")?;
        ac.register_library("libC", "builtin:elemlib")?;
        let a = DenseMatrix::from_vec(800, 64, random_matrix(1, 800, 64))?;
        let al_a = ac.send_dense(&a, LayoutKind::RowBlock)?;
        // call through both "libraries"
        let (out, _) = ac.run(
            "libA",
            "fro_norm",
            alchemist::ali::params::ParamsBuilder::new().matrix("A", al_a.handle()).build(),
        )?;
        let norm = out[0].1.as_f64()?;
        // truncated SVD through "libC" (raw run(); the wrappers module
        // assumes the conventional "elemlib" registration name)
        let (_, mats) = ac.run(
            "libC",
            "truncated_svd",
            alchemist::ali::params::ParamsBuilder::new()
                .matrix("A", al_a.handle())
                .i64("k", 8)
                .build(),
        )?;
        let s = ac.fetch_dense(&mats[1])?;
        assert!(s.get(0, 0) > 0.0);
        ac.stop()?;
        Ok((norm, ids))
    });

    // Application 2: one executor, 3 workers, library C only.
    let addr2 = addr.clone();
    let app2 = std::thread::spawn(move || -> alchemist::Result<(f64, Vec<u32>)> {
        let mut ac = AlchemistContext::connect(&addr2, "application-2")?;
        ac.request_workers(3)?;
        let ids = ac.workers().iter().map(|w| w.id).collect::<Vec<_>>();
        ac.register_library("libC", "builtin:elemlib")?;
        let b = DenseMatrix::from_vec(300, 40, random_matrix(2, 300, 40))?;
        let al_b = ac.send_dense(&b, LayoutKind::RowBlock)?;
        let (out, _) = ac.run(
            "libC",
            "fro_norm",
            alchemist::ali::params::ParamsBuilder::new().matrix("A", al_b.handle()).build(),
        )?;
        let norm = out[0].1.as_f64()?;
        ac.stop()?;
        Ok((norm, ids))
    });

    let (norm1, group1) = app1.join().expect("app1 panicked")?;
    let (norm2, group2) = app2.join().expect("app2 panicked")?;

    println!("app1: ‖A‖_F = {norm1:.3} on worker group {group1:?}");
    println!("app2: ‖B‖_F = {norm2:.3} on worker group {group2:?}");

    // Groups must be disjoint (Fig 2's group I / group II).
    for w in &group1 {
        assert!(!group2.contains(w), "worker groups overlap");
    }
    println!("worker groups are disjoint ✓");

    // Verify norms against local compute.
    let a = DenseMatrix::from_vec(800, 64, random_matrix(1, 800, 64))?;
    let b = DenseMatrix::from_vec(300, 40, random_matrix(2, 300, 40))?;
    assert!((norm1 - a.frobenius_norm()).abs() < 1e-9);
    assert!((norm2 - b.frobenius_norm()).abs() < 1e-9);
    println!("results verified ✓");

    // After both sessions closed, all 10 workers are reusable.
    let mut ac = AlchemistContext::connect(&addr, "application-3")?;
    ac.request_workers(10)?;
    println!("all {} workers returned to the pool ✓", ac.workers().len());
    ac.stop()?;
    server.shutdown();
    Ok(())
}
