//! Print the builtin library's routine table, generated straight from
//! the `RoutineRegistry` specs — the same data a remote client gets via
//! `describe_routines()` (protocol v6 `DescribeRoutines`).
//!
//! `cargo run --release --example describe_routines`
//!
//! The output is the markdown block embedded in rust/README.md between
//! the `routine-table` markers; CI diffs the two
//! (`scripts/check_routine_table.sh`), so the docs can never drift from
//! the registry.

use alchemist::ali::elemlib::ElemLib;
use alchemist::ali::Library;

fn main() {
    let lib = ElemLib::new();
    let reg = lib.registry().expect("elemlib publishes routine specs");
    println!("| routine | params | outputs | summary |");
    println!("|---|---|---|---|");
    for spec in reg.specs() {
        let params: Vec<String> = spec
            .params
            .iter()
            .map(|p| {
                let opt = if p.required { "" } else { "?" };
                format!("`{}{}: {}`", p.name, opt, p.ty.name())
            })
            .collect();
        let outputs = if spec.outputs.is_empty() {
            "—".to_string()
        } else {
            spec.outputs
                .iter()
                .map(|o| format!("`{}`", o.name))
                .collect::<Vec<_>>()
                .join(", ")
        };
        println!("| `{}` | {} | {} | {} |", spec.name, params.join(", "), outputs, spec.summary);
    }
}
