"""Pure-jnp oracles for every exported graph. The pytest suite asserts the
Pallas kernel and the L2 graphs against these before anything is exported."""

import jax.numpy as jnp


def gemm_acc_ref(x, y, acc):
    """C = acc + x @ y."""
    return acc + jnp.dot(x, y, preferred_element_type=acc.dtype)


def gemv_acc_ref(a, x, acc):
    """y = acc + A @ x (x, acc are column vectors shaped (n, 1)/(m, 1))."""
    return acc + jnp.dot(a, x, preferred_element_type=acc.dtype)


def gevm_acc_ref(a, x, acc):
    """y = acc + A^T @ x."""
    return acc + jnp.dot(a.T, x, preferred_element_type=acc.dtype)


def gram_matvec_ref(a, v):
    """w = A^T (A v) — one Lanczos operator application on a row panel."""
    return jnp.dot(a.T, jnp.dot(a, v))
