"""L1: tiled GEMM-accumulate Pallas kernel.

This is the node-local compute hot spot of the reproduced system: the
"MPI library" (our ElemLib) decomposes distributed GEMM / Gram matvecs into
fixed-shape tile products, and each tile product is this kernel.

TPU-idiomatic structure (see DESIGN.md §Hardware-Adaptation):
  * the (M, N, K) iteration space is expressed as a Pallas grid
    (m_tiles, n_tiles, k_tiles) with the contraction dimension innermost,
  * BlockSpecs stage (bm x bk) / (bk x bn) operand tiles through VMEM —
    the same HBM<->VMEM schedule a CPU version gets from cache blocking,
  * the output ref doubles as the accumulator across the k grid steps,
    which is the standard MXU accumulation pattern.

interpret=True is mandatory on this image: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. The kernel still lowers into
the surrounding jax graph and ships in the same HLO artifact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_acc_kernel(x_ref, y_ref, acc_ref, o_ref):
    """One (bm, bn) output tile; k is the innermost grid dimension.

    o = acc + sum_k x[:, k] @ y[k, :].  On the first k step the accumulator
    tile is loaded from `acc_ref`; later steps accumulate in place.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = acc_ref[...]

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm_acc(x, y, acc, *, bm=128, bn=128, bk=128):
    """Tiled C = acc + x @ y via the Pallas kernel.

    Shapes must tile evenly; the Rust runtime pads panels to the artifact's
    static shape, so the AOT path always satisfies this.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2 and acc.shape == (m, n)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gemm_acc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), acc.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y, acc)
