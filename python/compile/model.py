"""L2: the jax compute graphs the "MPI library" (ElemLib, Rust side) calls.

Each function here is AOT-lowered by aot.py into one HLO-text artifact with a
fixed (tile) shape; the Rust runtime pads/tiles arbitrary distributed-matrix
panels onto these shapes (rust/src/runtime/tiling.rs). The GEMM tile calls
the L1 Pallas kernel so the kernel lowers into the same artifact.

Everything is f64 by default (the paper's matrices are double precision);
f32 variants of the GEMM tile are also exported for the ablation bench.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from compile.kernels.gemm_pallas import gemm_acc


def gemm_acc_graph(x, y, acc):
    """C = acc + x @ y over one (bm, bk)x(bk, bn) tile — Pallas inside."""
    # Block size: one VMEM-resident sub-tile per grid step. 128x128 f64 is
    # 128 KiB/operand — comfortably inside a 16 MiB VMEM budget with double
    # buffering; see DESIGN.md for the footprint table.
    return (gemm_acc(x, y, acc, bm=128, bn=128, bk=128),)


def gemv_acc_graph(a, x, acc):
    """y = acc + A @ x; x and acc are (k, 1)/(m, 1) column vectors.

    Plain jnp: XLA fuses this into a single dot; a Pallas grid adds nothing
    for a bandwidth-bound matvec tile.
    """
    return (acc + jnp.dot(a, x, preferred_element_type=acc.dtype),)


def gevm_acc_graph(a, x, acc):
    """y = acc + A^T @ x (transpose matvec for the Gram operator)."""
    return (acc + jnp.dot(a.T, x, preferred_element_type=acc.dtype),)


def gram_matvec_graph(a, v):
    """w = A^T (A v) on one row panel — a full Lanczos operator application
    fused into one artifact (both halves in a single executable, saving one
    PJRT round trip per panel per iteration)."""
    t = jnp.dot(a, v, preferred_element_type=v.dtype)
    return (jnp.dot(a.T, t, preferred_element_type=v.dtype),)


# ---------------------------------------------------------------------------
# Artifact registry: name -> (graph fn, example arg shapes)
# ---------------------------------------------------------------------------

def _s(shape, dt):
    return jax.ShapeDtypeStruct(shape, dt)


def artifact_specs():
    """Every artifact exported by `make artifacts`.

    Tile sizes: 256 is the test/small-problem tile; 1024 amortizes PJRT
    per-call overhead on the bench matrices (see EXPERIMENTS.md §Perf).
    """
    f32, f64 = jnp.float32, jnp.float64
    specs = {}
    for t in (256, 1024):
        specs[f"gemm_acc_f64_{t}"] = (
            gemm_acc_graph, (_s((t, t), f64), _s((t, t), f64), _s((t, t), f64)))
        specs[f"gemm_acc_f32_{t}"] = (
            gemm_acc_graph, (_s((t, t), f32), _s((t, t), f32), _s((t, t), f32)))
    for t in (256, 1024):
        specs[f"gemv_acc_f64_{t}"] = (
            gemv_acc_graph, (_s((t, t), f64), _s((t, 1), f64), _s((t, 1), f64)))
        specs[f"gevm_acc_f64_{t}"] = (
            gevm_acc_graph, (_s((t, t), f64), _s((t, 1), f64), _s((t, 1), f64)))
    # Fused Gram matvec on a fixed row-panel tile (rows x n tile).
    for rows, n in ((1024, 256), (4096, 256), (4096, 1024)):
        specs[f"gram_matvec_f64_{rows}x{n}"] = (
            gram_matvec_graph, (_s((rows, n), f64), _s((n, 1), f64)))
    return specs
