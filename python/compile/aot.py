"""AOT exporter: lower every L2 graph to HLO *text* artifacts.

HLO text (NOT serialized HloModuleProto / jax .serialize()): jax >= 0.5
emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run via `make artifacts`; a manifest.json records shapes/dtypes so the Rust
runtime can validate its tiling glue against what was actually exported.
"""

import argparse
import json
import os

import jax
jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from compile.model import artifact_specs

_DTYPE_NAMES = {"float32": "f32", "float64": "f64"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, (fn, args) in artifact_specs().items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {"shape": list(a.shape), "dtype": _DTYPE_NAMES[str(a.dtype)]}
                for a in args
            ],
            "chars": len(text),
        }
        print(f"  {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts/model.hlo.txt",
                   help="stamp file path; artifacts land in its directory")
    args = p.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    manifest = export_all(out_dir)
    # Stamp file doubles as the Make target; lists what was exported.
    with open(args.out, "w") as f:
        f.write("\n".join(sorted(manifest)) + "\n")
    print(f"exported {len(manifest)} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
