"""Pallas GEMM kernel vs the pure-jnp oracle — the core L1 correctness
signal. hypothesis sweeps shapes/dtypes/block sizes."""

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gemm_pallas import gemm_acc
from compile.kernels import ref


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.float64, 1e-12)])
@pytest.mark.parametrize("m,n,k", [(8, 8, 8), (64, 32, 16), (128, 128, 128)])
def test_gemm_acc_matches_ref(dtype, tol, m, n, k):
    x, y, acc = _rand((m, k), dtype, 0), _rand((k, n), dtype, 1), _rand((m, n), dtype, 2)
    got = gemm_acc(x, y, acc, bm=min(m, 32), bn=min(n, 32), bk=min(k, 32))
    want = ref.gemm_acc_ref(x, y, acc)
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_gemm_acc_multi_k_step_accumulates():
    # k spans several grid steps; exercises the pl.when init + accumulate path.
    x, y, acc = _rand((32, 96), jnp.float64, 3), _rand((96, 32), jnp.float64, 4), _rand((32, 32), jnp.float64, 5)
    got = gemm_acc(x, y, acc, bm=16, bn=16, bk=16)
    np.testing.assert_allclose(got, ref.gemm_acc_ref(x, y, acc), rtol=1e-12)


def test_gemm_acc_zero_acc_is_plain_matmul():
    x, y = _rand((64, 64), jnp.float64, 6), _rand((64, 64), jnp.float64, 7)
    got = gemm_acc(x, y, jnp.zeros((64, 64), jnp.float64), bm=32, bn=32, bk=32)
    np.testing.assert_allclose(got, x @ y, rtol=1e-12)


def test_gemm_acc_rejects_uneven_tiles():
    x, y, acc = (jnp.zeros((10, 8)), jnp.zeros((8, 8)), jnp.zeros((10, 8)))
    with pytest.raises(AssertionError):
        gemm_acc(x, y, acc, bm=4, bn=4, bk=4)  # m=10 not divisible by 4


_dims = st.sampled_from([8, 16, 24, 32, 48, 64])
_blocks = st.sampled_from([8, 16, 32])


@settings(max_examples=30, deadline=None)
@given(m=_dims, n=_dims, k=_dims, bm=_blocks, bn=_blocks, bk=_blocks,
       dtype=st.sampled_from([jnp.float32, jnp.float64]),
       seed=st.integers(0, 2**31 - 1))
def test_gemm_acc_hypothesis_sweep(m, n, k, bm, bn, bk, dtype, seed):
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        return  # uneven tilings are rejected (covered above)
    x, y, acc = _rand((m, k), dtype, seed), _rand((k, n), dtype, seed + 1), _rand((m, n), dtype, seed + 2)
    got = gemm_acc(x, y, acc, bm=bm, bn=bn, bk=bk)
    tol = 1e-4 if dtype == jnp.float32 else 1e-11
    np.testing.assert_allclose(got, ref.gemm_acc_ref(x, y, acc), rtol=tol, atol=tol)
