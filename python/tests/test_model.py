"""L2 graphs vs oracles + artifact-spec sanity. These run on the exact
functions aot.py lowers, so a green run here certifies the export set."""

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _rand(shape, dtype=jnp.float64, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def test_gemm_acc_graph_matches_ref():
    x, y, acc = _rand((256, 256)), _rand((256, 256), seed=1), _rand((256, 256), seed=2)
    (got,) = model.gemm_acc_graph(x, y, acc)
    # atol: tiled k-accumulation reorders sums vs the oracle's single dot
    np.testing.assert_allclose(got, ref.gemm_acc_ref(x, y, acc), rtol=1e-10, atol=1e-10)


def test_gemv_acc_graph_matches_ref():
    a, x, acc = _rand((256, 256)), _rand((256, 1), seed=1), _rand((256, 1), seed=2)
    (got,) = model.gemv_acc_graph(a, x, acc)
    np.testing.assert_allclose(got, ref.gemv_acc_ref(a, x, acc), rtol=1e-12)


def test_gevm_acc_graph_matches_ref():
    a, x, acc = _rand((256, 256)), _rand((256, 1), seed=1), _rand((256, 1), seed=2)
    (got,) = model.gevm_acc_graph(a, x, acc)
    np.testing.assert_allclose(got, ref.gevm_acc_ref(a, x, acc), rtol=1e-12)


def test_gram_matvec_graph_matches_ref():
    a, v = _rand((1024, 256)), _rand((256, 1), seed=1)
    (got,) = model.gram_matvec_graph(a, v)
    np.testing.assert_allclose(got, ref.gram_matvec_ref(a, v), rtol=1e-12)


def test_gram_matvec_is_symmetric_psd_operator():
    # Lanczos requires a symmetric PSD operator: v^T G w == w^T G v, v^T G v >= 0.
    a = _rand((512, 128))
    v, w = _rand((128, 1), seed=1), _rand((128, 1), seed=2)
    (gv,) = model.gram_matvec_graph(a, v)
    (gw,) = model.gram_matvec_graph(a, w)
    assert abs(float((w.T @ gv)[0, 0]) - float((v.T @ gw)[0, 0])) < 1e-8
    assert float((v.T @ gv)[0, 0]) >= 0


def test_artifact_specs_complete_and_well_formed():
    specs = model.artifact_specs()
    # every artifact the Rust runtime expects must be present
    for required in ["gemm_acc_f64_256", "gemm_acc_f64_1024",
                     "gemm_acc_f32_256", "gemm_acc_f32_1024",
                     "gemv_acc_f64_256", "gevm_acc_f64_256",
                     "gemv_acc_f64_1024", "gevm_acc_f64_1024",
                     "gram_matvec_f64_4096x256"]:
        assert required in specs, required
    for name, (fn, args) in specs.items():
        assert callable(fn)
        for a in args:
            assert all(d > 0 for d in a.shape), name


@pytest.mark.parametrize("name", ["gemm_acc_f64_256", "gemv_acc_f64_256",
                                  "gevm_acc_f64_256", "gram_matvec_f64_1024x256"])
def test_specs_lower_to_hlo_text(name):
    # Lowering (not just tracing) must succeed for export; checks the HLO
    # text conversion path end to end for a representative subset.
    from compile.aot import to_hlo_text
    fn, args = model.artifact_specs()[name]
    text = to_hlo_text(jax.jit(fn).lower(*args))
    assert "ENTRY" in text and len(text) > 100
